"""The process backend's substrate: FileStore atomicity + accounting across
real processes, mtime leases and poison files, FileBarrier, payload-true
byte charging, bandwidth throttling, registry availability reporting, and
process-backend end-to-end runs (parity itself lives in test_backends.py /
test_faults.py, parametrized over backend='process')."""
import os
import threading
import time

import numpy as np
import pytest

from repro.serverless.backends import (
    ProcessBackend,
    available_backends,
    backend_availability,
    get_backend,
)
from repro.serverless.backends.process_worker import (
    FileBarrier,
    FileStore,
    _true_payload_nbytes,
)
from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
    assert_store_drained,
)

pytestmark = pytest.mark.skipif(os.name != "posix",
                                reason="FileStore needs POSIX flock")


def _mkstore(tmp_path, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("lease_timeout", 0.3)
    return FileStore(str(tmp_path / "store"), **kw)


# ------------------------------------------------------------------ FileStore
def test_file_store_round_trip_and_accounting(tmp_path):
    store = _mkstore(tmp_path)
    store.put("k0/r0/m0/act0", 128.0, value={"x": 1})
    assert "k0/r0/m0/act0" in store and store.live_bytes == 128.0
    value, nb = store.take("k0/r0/m0/act0", return_nbytes=True)
    assert value == {"x": 1} and nb == 128.0
    assert len(store) == 0 and store.live_bytes == 0.0
    assert store.stats.puts == store.stats.deletes == 1
    assert_store_drained(store)


def test_file_store_blocks_until_visible(tmp_path):
    store = _mkstore(tmp_path)
    got = {}

    def consumer():
        got["v"] = store.take("x")

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()
    store.put("x", 64.0, value="payload")
    t.join(timeout=10.0)
    assert got["v"] == "payload"


def test_file_store_overwrite_counts_implicit_delete(tmp_path):
    store = _mkstore(tmp_path)
    store.put("k", 100.0)
    store.put("k", 40.0)
    assert store.live_bytes == pytest.approx(40.0)
    store.delete("k")
    assert store.stats.puts == store.stats.deletes == 2
    assert store.stats.bytes_deleted == pytest.approx(store.stats.bytes_in)
    assert_store_drained(store)


def test_accounting_survives_a_second_client(tmp_path):
    """stats.json is the shared truth: a second FileStore client over the
    same root (another process, in production) sees the same counters."""
    a = _mkstore(tmp_path)
    a.put("k0/r0/m0/act0", 32.0, value=b"v")
    b = FileStore(str(tmp_path / "store"), timeout=5.0)
    assert b.stats.puts == 1 and b.live_bytes == 32.0
    assert b.take("k0/r0/m0/act0") == b"v"
    assert a.stats.deletes == 1 and a.live_bytes == 0.0


def test_stale_mtime_lease_raises_producer_dead(tmp_path):
    """A producer whose heartbeat file mtime froze (SIGKILL'd process) fails
    its consumers over without burning the get timeout."""
    store = _mkstore(tmp_path, timeout=30.0, lease_timeout=0.2)
    store.heartbeat((0, 0))
    time.sleep(0.4)                      # mtime goes stale by itself
    t0 = time.monotonic()
    with pytest.raises(ProducerDeadError, match="stopped heartbeating"):
        store.get("k0/r0/m0/act0")
    assert time.monotonic() - t0 < 5.0


def test_dead_marker_fails_over_immediately(tmp_path):
    store = _mkstore(tmp_path, timeout=30.0)
    store.mark_dead((0, 0))
    with pytest.raises(ProducerDeadError, match="died"):
        store.get("k0/r0/m0/act0")


def test_poison_file_aborts_waiters_and_revives(tmp_path):
    store = _mkstore(tmp_path, timeout=30.0)
    errs = {}

    def consumer():
        try:
            store.get("k0/r0/m0/act0")
        except BaseException as e:      # noqa: BLE001
            errs["e"] = e

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    store.abort(RuntimeError("worker s0r0 exploded"))
    t.join(timeout=10.0)
    assert isinstance(errs["e"], StoreAbortedError)
    assert "exploded" in str(errs["e"])
    # first poison wins; revive clears it
    store.abort(RuntimeError("collateral"))
    assert "exploded" in store._poison_text()
    store.revive()
    assert store._poison_text() is None


def test_get_timeout_diagnoses_missing_object(tmp_path):
    store = _mkstore(tmp_path, timeout=0.05)
    with pytest.raises(TimeoutError, match="never became visible"):
        store.get("missing")


def test_file_barrier_meets_across_threads(tmp_path):
    store = _mkstore(tmp_path)
    n, out = 3, []

    def party(i):
        b = FileBarrier(store, "k0-s0", n, i, timeout=10.0)
        b.wait()
        out.append(i)
        b.wait()                         # second generation works too

    ts = [threading.Thread(target=party, args=(i,)) for i in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=15.0)
    assert sorted(out) == [0, 1, 2]


def test_file_barrier_breaks_on_poison(tmp_path):
    store = _mkstore(tmp_path)
    store.abort(RuntimeError("peer died"))
    b = FileBarrier(store, "k0-s0", 2, 0, timeout=5.0)
    with pytest.raises(threading.BrokenBarrierError):
        b.wait()


# ----------------------------------------------------- payload-true accounting
def test_payload_true_charges_real_nbytes(tmp_path):
    """Charged bytes equal the sum of the *real* payload sizes — the
    calibrated axis the ROADMAP asks for — regardless of the modeled sizes
    the engine passes."""
    store = _mkstore(tmp_path, payload_true=True)
    payloads = {
        "k0/r0/m0/act0": np.arange(1000, dtype=np.float32),     # activation
        "k0/r0/m0/grad0": np.ones((16, 8), dtype=np.float32),   # gradient
        "k0/sync0/red/0": np.zeros(37, dtype=np.float64),       # sync chunk
    }
    for key, arr in payloads.items():
        store.put(key, 1.0, value=arr)   # modeled size deliberately wrong
    want = float(sum(a.nbytes for a in payloads.values()))
    assert store.stats.bytes_in == pytest.approx(want)
    got = 0.0
    for key, arr in payloads.items():
        value, nb = store.take(key, return_nbytes=True)
        np.testing.assert_array_equal(value, arr)
        got += nb
    assert got == pytest.approx(want)
    assert store.stats.bytes_out == pytest.approx(want)
    assert_store_drained(store)


def test_true_payload_nbytes_falls_back_to_wire_size():
    arr = np.arange(10, dtype=np.int64)
    assert _true_payload_nbytes(arr, b"") == arr.nbytes
    assert _true_payload_nbytes(b"12345", b"x") == 5.0
    assert _true_payload_nbytes({"no": "nbytes"}, b"123456") == 6.0


def test_without_payload_true_modeled_sizes_are_charged(tmp_path):
    store = _mkstore(tmp_path)
    store.put("k", 999.0, value=np.zeros(4, dtype=np.float32))
    assert store.stats.bytes_in == 999.0
    store.delete("k")


# ------------------------------------------------------------------- throttle
def test_throttle_transfer_time_tracks_bytes_over_bandwidth(tmp_path):
    """Wall-clock put+take of B real bytes at bandwidth W takes ~B/W each
    way (within scheduling tolerance)."""
    bw = 2e6                             # 2 MB/s
    store = _mkstore(tmp_path, payload_true=True, bandwidth=bw, t_lat=0.0)
    arr = np.zeros(250_000, dtype=np.float32)        # 1 MB -> 0.5 s per leg
    expect = arr.nbytes / bw
    t0 = time.monotonic()
    store.put("k0/r0/m0/act0", 0.0, value=arr)
    up = time.monotonic() - t0
    t0 = time.monotonic()
    store.take("k0/r0/m0/act0")
    down = time.monotonic() - t0
    for leg in (up, down):
        assert leg >= expect * 0.9
        assert leg <= expect * 1.6 + 0.2        # generous: CI schedulers
    assert store.stats.bytes_in == arr.nbytes


def test_unthrottled_transfers_do_not_sleep(tmp_path):
    store = _mkstore(tmp_path, payload_true=True)
    t0 = time.monotonic()
    store.put("k", 0.0, value=np.zeros(250_000, dtype=np.float32))
    store.take("k")
    assert time.monotonic() - t0 < 0.5


# --------------------------------------------------- registry / availability
def test_process_backend_registered_and_available():
    assert "process" in available_backends()
    be = get_backend("process")
    assert isinstance(be, ProcessBackend)
    assert be.wall_clock and be.hosts_programs
    avail = backend_availability()
    assert avail["process"] is None          # posix host (see pytestmark)
    assert avail["emulated"] is None and avail["local"] is None


def test_unknown_backend_error_lists_names_and_availability():
    with pytest.raises(KeyError) as ei:
        get_backend("s3-but-misspelled")
    msg = str(ei.value)
    assert "unknown execution backend" in msg
    for name in ("emulated", "local", "process", "aws", "oss"):
        assert name in msg
    import importlib.util

    if importlib.util.find_spec("boto3") is None:
        assert "boto3 not installed" in msg


def test_process_backend_caps_worker_processes():
    from types import SimpleNamespace

    with pytest.raises(ValueError, match="caps at"):
        ProcessBackend().open(SimpleNamespace(S=9, d=8))     # 72 > 64


def test_api_emulate_calibration_flags_require_process_backend():
    from repro.api import session

    s = (session("bert-large", platform="aws", global_batch=64)
         .plan(merge_to=6, d_options=(1, 2)))
    with pytest.raises(ValueError, match="process"):
        s.emulate(steps=1, throttle=True)
    with pytest.raises(ValueError, match="process"):
        s.emulate(steps=1, backend="local", payload_true=True)


# ------------------------------------------------------------------ end to end
def test_throttled_run_is_slower_and_conserved():
    """End-to-end: the same timing-only plan runs measurably slower with the
    bandwidth throttle on, and the byte accounting stays identical."""
    from test_backends import _timing_plan

    from repro.serverless.platform import AWS_LAMBDA
    from repro.serverless.runtime import run_plan

    prof, cfg = _timing_plan(d=2)
    fast = run_plan(prof, AWS_LAMBDA, cfg, 32, steps=1, pipelined_sync=True,
                    backend=ProcessBackend())
    total_bytes = fast.store_stats.bytes_in
    # bandwidth sized so uplink sleeps alone total ~8s across the workers:
    # even spread over the S*d=4 processes leaves ~2s on the critical path
    bw = total_bytes / 8.0
    slow = run_plan(prof, AWS_LAMBDA, cfg, 32, steps=1, pipelined_sync=True,
                    backend=ProcessBackend(throttle=True, bandwidth=bw))
    assert slow.store_stats.bytes_in == pytest.approx(total_bytes)
    assert (slow.store_stats.puts, slow.store_stats.gets) == \
        (fast.store_stats.puts, fast.store_stats.gets)
    assert slow.t_total > fast.t_total + 1.0
