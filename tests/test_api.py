"""Unified deployment API: DeploymentPlan JSON round-trip + content hash,
fingerprint compatibility guard, Session fluency, parity of the plan-replay
paths against the raw (profile, platform, config, M) call paths, and smoke
tests for every ``python -m repro`` CLI subcommand."""
import dataclasses
import json

import pytest

from _hypo import given, settings, st

from repro.api import DeploymentPlan, PlanCompatibilityError, session
from repro.api.plan import profile_fingerprint
from repro.cli import main as cli_main
from repro.core import planner
from repro.core.partition import merge_layers
from repro.core.perfmodel import evaluate
from repro.core.profiler import paper_model_profile, resolve_profile
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA
from repro.serverless.runtime import run_plan
from repro.serverless.simulator import simulate_funcpipe

ALPHA = (1.0, 2**16 * 1e-9)
FAST = dict(merge_to=6, d_options=(1, 2, 4))


@pytest.fixture(scope="module")
def bert_session():
    return session("bert-large", platform="aws", global_batch=64).plan(
        alpha=ALPHA, **FAST)


# ----------------------------------------------------------- serialization
def test_json_round_trip_and_stable_hash(bert_session):
    plan = bert_session.deployment_plan
    blob = plan.to_json()
    again = DeploymentPlan.from_json(blob)
    assert again == plan
    assert again.content_hash == plan.content_hash
    # hash is over content: provenance timing must not affect it
    assert dataclasses.replace(plan, solve_seconds=99.0).content_hash \
        == plan.content_hash
    # ... but decisions must
    assert dataclasses.replace(plan, d=plan.d * 2).content_hash \
        != plan.content_hash


def test_from_json_rejects_bad_schema(bert_session):
    d = json.loads(bert_session.deployment_plan.to_json())
    with pytest.raises(PlanCompatibilityError):
        DeploymentPlan.from_json(json.dumps({**d, "version": 99}))
    with pytest.raises(PlanCompatibilityError):
        DeploymentPlan.from_json(json.dumps({**d, "surprise": 1}))
    d.pop("x")
    with pytest.raises(PlanCompatibilityError):
        DeploymentPlan.from_json(json.dumps(d))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_round_trip_property(data):
    """Any plan-shaped value survives to_json/from_json exactly, and equal
    plans hash equal (solver provenance aside)."""
    L = data.draw(st.integers(min_value=2, max_value=8))
    x = tuple(data.draw(st.integers(0, 1)) for _ in range(L - 1))
    z = tuple(data.draw(st.integers(0, 7)) for _ in range(L))
    plan = DeploymentPlan(
        model=data.draw(st.sampled_from(["bert-large", "resnet101", "m"])),
        platform=data.draw(st.sampled_from(["aws_lambda", "alibaba_fc"])),
        x=x, z=z, d=data.draw(st.sampled_from([1, 2, 4, 8])),
        total_micro_batches=data.draw(st.integers(1, 64)),
        alpha=(1.0, data.draw(st.floats(0, 1e-2, allow_nan=False))),
        pipelined_sync=data.draw(st.booleans()),
        merge_to=data.draw(st.one_of(st.none(), st.integers(2, 16))),
        seq=data.draw(st.one_of(st.none(), st.integers(8, 512))),
        micro_batch=data.draw(st.one_of(st.none(), st.integers(1, 8))),
        profile_fingerprint="ab" * 8,
        t_iter=data.draw(st.floats(0, 1e4, allow_nan=False)),
        c_iter=data.draw(st.floats(0, 1e2, allow_nan=False)),
        objective=data.draw(st.floats(0, 1e4, allow_nan=False)),
        solver="cd", engine="batch",
        solve_seconds=data.draw(st.floats(0, 1e3, allow_nan=False)),
    )
    again = DeploymentPlan.from_json(plan.to_json())
    assert again == plan
    assert again.content_hash == plan.content_hash


# ----------------------------------------------------- dp-engine plan artifact
@pytest.fixture(scope="module")
def dp_session():
    return session("bert-large", platform="aws", global_batch=64).plan(
        alpha=ALPHA, engine="dp", **FAST)


def test_dp_plan_round_trip_and_replay(dp_session):
    """A DeploymentPlan produced by engine='dp' survives JSON exactly and
    replays through the simulator and the storage-backed engine."""
    plan = dp_session.deployment_plan
    assert plan.engine == "dp"
    again = DeploymentPlan.from_json(plan.to_json())
    assert again == plan
    assert again.content_hash == plan.content_hash
    sim = plan.simulate()
    eng = plan.emulate(steps=1)
    assert sim.t_iter > 0 and eng.t_iter > 0
    # solver-predicted numbers replay: simulate tracks the closed form
    assert sim.t_iter == pytest.approx(plan.t_iter, rel=0.1)


def test_content_hash_stable_across_engines(dp_session, bert_session):
    """Identical decisions hash identically whatever engine found them:
    solver/engine/solve_seconds are provenance, excluded from the hash."""
    plan = dp_session.deployment_plan
    for prov in (dict(engine="batch"), dict(solver="exhaustive"),
                 dict(solve_seconds=1234.5)):
        assert dataclasses.replace(plan, **prov).content_hash \
            == plan.content_hash
    assert dataclasses.replace(plan, z=tuple(plan.z[::-1])).content_hash \
        != plan.content_hash
    # at this depth the CD heuristic finds the DP optimum, so the two
    # engines' plans are the same deployment — and hash the same
    batch_plan = bert_session.deployment_plan
    assert (batch_plan.x, batch_plan.z, batch_plan.d) \
        == (plan.x, plan.z, plan.d)
    assert batch_plan.content_hash == plan.content_hash


def test_dp_full_depth_plan_records_unmerged(tmp_path):
    """merge_to=None round-trips and resolves against the unmerged profile."""
    s = session("bert-large", platform="aws", global_batch=32).plan(
        alpha=ALPHA, engine="dp", merge_to=None, d_options=(1, 2))
    plan = s.deployment_plan
    assert plan.merge_to is None
    assert len(plan.z) == resolve_profile("bert-large", AWS_LAMBDA).L
    path = tmp_path / "plan_dp.json"
    plan.save(path)
    loaded = DeploymentPlan.load(path)
    assert loaded == plan
    loaded.resolve()                      # fingerprint-checked rebuild
    assert loaded.simulate().t_iter > 0


# ------------------------------------------------------------- fingerprint
def test_resolve_profile_reduced_arch_spelling():
    """The numeric emulation mode records `<arch>@reduced<L>`; it must
    resolve to the same profile the mode built, so saved plans replay."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.core.profiler import arch_model_profile

    cfg = dc.replace(get_config("phi3-mini-3.8b").reduced(), n_layers=4)
    direct = arch_model_profile(cfg, AWS_LAMBDA, seq=16, micro_batch=2)
    via_id = resolve_profile("phi3-mini-3.8b@reduced4", AWS_LAMBDA,
                             seq=16, micro_batch=2)
    assert profile_fingerprint(via_id) == profile_fingerprint(direct)
    with pytest.raises(KeyError):
        resolve_profile("phi3-mini-3.8b@huge", AWS_LAMBDA)


def test_fingerprint_tracks_profile_content():
    a = paper_model_profile("bert-large", AWS_LAMBDA)
    b = paper_model_profile("bert-large", AWS_LAMBDA)
    assert profile_fingerprint(a) == profile_fingerprint(b)
    assert profile_fingerprint(a) != profile_fingerprint(
        paper_model_profile("bert-large", ALIBABA_FC))
    assert profile_fingerprint(a) != profile_fingerprint(merge_layers(a, 8))


def test_fingerprint_catches_platform_drift(bert_session):
    """Pricing/bandwidth/latency drift doesn't change the layer tables, but
    a replayed plan must still refuse: the platform is folded into the
    recorded fingerprint."""
    plan = bert_session.deployment_plan
    drifted = dataclasses.replace(AWS_LAMBDA, price_per_gb_s=1e-3)
    prof = merge_layers(
        resolve_profile("bert-large", AWS_LAMBDA), plan.merge_to)
    plan.resolve(profile=prof, platform=AWS_LAMBDA)        # unchanged: fine
    with pytest.raises(PlanCompatibilityError, match="fingerprint"):
        plan.resolve(profile=prof, platform=drifted)


def test_mismatched_fingerprint_raises(bert_session):
    plan = bert_session.deployment_plan
    bad = dataclasses.replace(plan, profile_fingerprint="0" * 16)
    with pytest.raises(PlanCompatibilityError, match="fingerprint mismatch"):
        bad.resolve()
    with pytest.raises(PlanCompatibilityError):
        bad.simulate()
    # a plan replayed against the wrong platform must refuse too
    wrong = dataclasses.replace(plan, platform="alibaba_fc")
    with pytest.raises(PlanCompatibilityError):
        wrong.resolve()
    # unknown model / platform names give the clear error, not KeyError
    with pytest.raises(PlanCompatibilityError):
        dataclasses.replace(plan, model="no-such-model").resolve()
    with pytest.raises(PlanCompatibilityError):
        dataclasses.replace(plan, platform="no-such-cloud").resolve()


# ------------------------------------------------------------------ parity
def test_replay_matches_in_memory_paths_exactly(bert_session):
    """simulate/emulate through the DeploymentPlan front door must be
    bit-identical to the old hand-threaded (profile, platform, config, M)
    call paths — including after a JSON round trip."""
    plan = DeploymentPlan.from_json(bert_session.deployment_plan.to_json())
    prof = merge_layers(
        resolve_profile("bert-large", AWS_LAMBDA), plan.merge_to)
    M = plan.total_micro_batches
    r = planner.solve(prof, AWS_LAMBDA, alpha=ALPHA, total_micro_batches=M,
                      merge_to=plan.merge_to, d_options=FAST["d_options"])
    assert r.config == plan.config

    old_sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
    old_eng = run_plan(r.profile, AWS_LAMBDA, r.config, M, steps=2)
    old_ev = evaluate(r.profile, AWS_LAMBDA, r.config, M)

    assert plan.simulate().t_iter == old_sim.t_iter
    assert plan.simulate().cost == old_sim.cost
    assert simulate_funcpipe(plan).t_iter == old_sim.t_iter  # direct accept
    assert plan.emulate(steps=2).t_iter == old_eng.t_iter
    assert run_plan(plan, steps=2).t_iter == old_eng.t_iter  # direct accept
    assert plan.evaluate().t_iter == old_ev.t_iter
    assert plan.t_iter == old_ev.t_iter


def test_funcpipe_baseline_accepts_deployment_plans(bert_session):
    from repro.serverless import frameworks

    plan = bert_session.deployment_plan
    res = frameworks.funcpipe_replay([plan, plan])
    assert len(res.sims) == 1                       # deduped identical configs
    assert res.deployment_plans == [plan]
    assert res.recommended_sim.t_iter == plan.simulate().t_iter


# ----------------------------------------------------------------- session
def test_session_fluent_chain(bert_session):
    s = bert_session.simulate().emulate(steps=1)
    assert s.sim_result is not None and s.engine_result is not None
    assert s.sim_result.t_iter == pytest.approx(s.deployment_plan.t_iter)
    assert s.plan_result.config == s.deployment_plan.config


def test_session_save_load_and_drift_guard(tmp_path):
    s = session("bert-large", platform="aws", global_batch=32).plan(
        alpha=ALPHA, **FAST)
    path = tmp_path / "plan.json"
    s.save_plan(path)
    s2 = session("bert-large", platform="aws", global_batch=32).load_plan(path)
    assert s2.deployment_plan == s.deployment_plan

    # a session whose freshly-built profile differs must refuse the plan
    blob = json.loads(path.read_text())
    blob["profile_fingerprint"] = "f" * 16
    path.write_text(json.dumps(blob))
    with pytest.raises(PlanCompatibilityError):
        session("bert-large", platform="aws", global_batch=32).load_plan(path)


def test_session_sweep_recommends():
    s = session("bert-large", platform="aws", global_batch=32).sweep(**FAST)
    assert len(s.plans) >= 1
    assert s.deployment_plan is s.plans[s.recommended]
    # every solver path produces a plan artifact
    for solver in ("tpdmp", "bayes"):
        s.plan(alpha=ALPHA, solver=solver, merge_to=6)
        assert s.deployment_plan.solver == solver


# --------------------------------------------------------------- plan cache
def test_plan_cache_hits_and_returns_identical_plan(tmp_path):
    cache_dir = tmp_path / "plans"
    s1 = session("bert-large", platform="aws", global_batch=64,
                 plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    assert s1.plan_cache.misses == 1 and s1.plan_cache.hits == 0
    assert list(cache_dir.glob("plan-*.json"))

    s2 = session("bert-large", platform="aws", global_batch=64,
                 plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    assert s2.plan_cache.hits == 1 and s2.plan_cache.misses == 0
    assert s2.deployment_plan == s1.deployment_plan
    assert s2.deployment_plan.content_hash == s1.deployment_plan.content_hash
    # the in-memory twin is rebuilt on hits, so sweep/recommend still work
    assert s2.plan_result.config == s1.plan_result.config
    assert s2.plan_result.objective == pytest.approx(s1.plan_result.objective)


def test_plan_cache_keys_on_solver_inputs(tmp_path):
    cache_dir = tmp_path / "plans"
    kw = dict(platform="aws", global_batch=64, plan_cache=cache_dir)
    session("bert-large", **kw).plan(alpha=ALPHA, **FAST)
    # a different objective weight must miss, not alias
    s = session("bert-large", **kw).plan(alpha=(1.0, 0.0), **FAST)
    assert s.plan_cache.hits == 0 and s.plan_cache.misses == 1
    # a different batch budget too
    s = session("bert-large", platform="aws", global_batch=32,
                plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    assert s.plan_cache.hits == 0


def test_plan_cache_corrupt_entry_degrades_to_solve(tmp_path):
    cache_dir = tmp_path / "plans"
    s1 = session("bert-large", platform="aws", global_batch=64,
                 plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    entry = next(cache_dir.glob("plan-*.json"))
    entry.write_text("{not json")
    s2 = session("bert-large", platform="aws", global_batch=64,
                 plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    assert s2.plan_cache.hits == 0 and s2.plan_cache.misses == 1
    # re-solved (solve_seconds is fresh provenance) to the identical decision
    assert s2.deployment_plan.content_hash == s1.deployment_plan.content_hash
    assert not entry.exists() or json.loads(entry.read_text())


def test_plan_cache_drifted_entry_counts_as_miss(tmp_path):
    """An entry that parses but fails the resolve check (fingerprint drift)
    must be evicted and counted as a miss, not a hit — the hit counter is
    what the CLI (and the CI cache gate) reports."""
    cache_dir = tmp_path / "plans"
    session("bert-large", platform="aws", global_batch=64,
            plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    entry = next(cache_dir.glob("plan-*.json"))
    blob = json.loads(entry.read_text())
    blob["profile_fingerprint"] = "f" * 16
    entry.write_text(json.dumps(blob))
    s2 = session("bert-large", platform="aws", global_batch=64,
                 plan_cache=cache_dir).plan(alpha=ALPHA, **FAST)
    assert s2.plan_cache.hits == 0 and s2.plan_cache.misses == 1
    assert s2.deployment_plan is not None    # re-solved
    # the drifted entry was evicted and replaced by the fresh solve
    fresh = json.loads(next(cache_dir.glob("plan-*.json")).read_text())
    assert fresh["profile_fingerprint"] != "f" * 16


def test_plan_cache_sweep_near_instant_on_rerun(tmp_path):
    cache_dir = tmp_path / "plans"
    s1 = session("bert-large", platform="aws", global_batch=32,
                 plan_cache=cache_dir).sweep(**FAST)
    n_solved = s1.plan_cache.misses
    assert n_solved >= 1
    s2 = session("bert-large", platform="aws", global_batch=32,
                 plan_cache=cache_dir).sweep(**FAST)
    assert s2.plan_cache.misses == 0 and s2.plan_cache.hits >= n_solved
    assert [p.content_hash for p in s2.plans] == \
        [p.content_hash for p in s1.plans]
    assert s2.recommended == s1.recommended


def test_cli_no_plan_cache_flag(tmp_path, capsys, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "cli-cache"))
    out1 = _run_cli(capsys, "plan", "--model", "bert-large", "--batch", "64",
                    "--fast")
    assert "[plan cache hit]" not in out1
    out2 = _run_cli(capsys, "plan", "--model", "bert-large", "--batch", "64",
                    "--fast")
    assert "[plan cache hit]" in out2
    out3 = _run_cli(capsys, "plan", "--model", "bert-large", "--batch", "64",
                    "--fast", "--no-plan-cache")
    assert "[plan cache hit]" not in out3


def test_session_rejects_unknown(tmp_path):
    with pytest.raises(KeyError):
        session("bert-large", platform="nope")
    with pytest.raises(KeyError):
        session("no-such-model").profile()
    with pytest.raises(ValueError):
        session("bert-large").plan(solver="gurobi", **FAST)
    with pytest.raises(ValueError, match="bayes"):
        session("bert-large").plan(solver="bayes", engine="dp", **FAST)


# --------------------------------------------------------------- CLI smoke
def _run_cli(capsys, *argv):
    rc = cli_main(list(argv))
    out = capsys.readouterr().out
    assert rc == 0, out
    return out


def test_cli_plan_simulate_emulate_replay(tmp_path, capsys):
    """Acceptance path: `repro plan -o f` then `repro simulate f` and
    `repro emulate f` replay the saved JSON bit-identically."""
    path = tmp_path / "plan.json"
    out = _run_cli(capsys, "plan", "--model", "bert-large", "--batch", "64",
                   "--fast", "-o", str(path))
    assert "wrote" in out
    plan = DeploymentPlan.load(path)

    sim_out = _run_cli(capsys, "simulate", str(path))
    eng_out = _run_cli(capsys, "emulate", str(path), "--steps", "2")
    sim = plan.simulate()
    eng = plan.emulate(steps=2)
    assert f"t_iter={sim.t_iter:.3f}s" in sim_out
    assert f"cost=${sim.cost:.6f}/iter" in sim_out
    assert f"t_iter={eng.t_iter:.3f}s" in eng_out
    assert plan.content_hash in sim_out


def test_cli_plan_engine_dp(tmp_path, capsys):
    """`repro plan --engine dp` plans at full depth by default, records the
    engine in the artifact, and the saved plan replays."""
    path = tmp_path / "plan_dp.json"
    out = _run_cli(capsys, "plan", "--model", "amoebanet-d18", "--batch", "32",
                   "--engine", "dp", "-o", str(path))
    assert "dp" in out
    plan = DeploymentPlan.load(path)
    assert plan.engine == "dp" and plan.merge_to is None
    _run_cli(capsys, "simulate", str(path))


def test_cli_sweep_engine_dp(capsys):
    out = _run_cli(capsys, "sweep", "--model", "amoebanet-d18", "--batch",
                   "16", "--engine", "dp", "--merge-to", "8")
    assert "engine=dp" in out
    assert "RECOMMENDED" in out


def test_cli_sweep(capsys, tmp_path):
    out = _run_cli(capsys, "sweep", "--model", "bert-large", "--batch", "32",
                   "--fast", "--save-dir", str(tmp_path / "plans"))
    assert "RECOMMENDED" in out
    assert "alpha2=" in out
    saved = list((tmp_path / "plans").glob("*.json"))
    assert saved, "sweep --save-dir wrote no plans"
    for p in saved:
        DeploymentPlan.load(p).resolve()    # all replayable


def test_cli_bench_list(capsys):
    out = _run_cli(capsys, "bench", "--list")
    assert "runtime_accuracy" in out and "planner" in out


def test_cli_train_dryrun_help(capsys):
    # the front door lists every subcommand (train/dryrun are pass-through;
    # importing repro.launch.dryrun sets XLA_FLAGS, so only `train --help`
    # is exercised in-process)
    with pytest.raises(SystemExit) as e:
        cli_main(["--help"])
    assert e.value.code == 0
    out = capsys.readouterr().out
    for sub in ("plan", "simulate", "emulate", "sweep", "bench", "train",
                "dryrun"):
        assert sub in out
    with pytest.raises(SystemExit) as e:
        cli_main(["train", "--help"])
    assert e.value.code == 0
    assert "usage" in capsys.readouterr().out.lower()


def test_launch_emulate_shim(capsys):
    from repro.launch import emulate

    rc = emulate.main(["--model", "bert-large", "--batch", "16", "--fast",
                       "--steps", "1"])
    assert rc == 0
    assert "engine[emulated]:" in capsys.readouterr().out


# --------------------------------------------------------- execution config
def test_execution_config_validation():
    from repro.serverless.execution import ExecutionConfig

    with pytest.raises(ValueError, match="steps"):
        ExecutionConfig(steps=0)
    with pytest.raises(ValueError, match="bandwidth"):
        ExecutionConfig(bandwidth=-1.0)
    with pytest.raises(ValueError, match="retries"):
        ExecutionConfig(retries=0)
    with pytest.raises(ValueError, match="checkpoint_every"):
        ExecutionConfig(checkpoint_every=0)
    # an explicit bandwidth is only meaningful as a throttle rate
    assert ExecutionConfig(bandwidth=1e6).throttle
    # the process-backend rule lives in ONE place: resolve_backend
    for bad in (ExecutionConfig(payload_true=True),
                ExecutionConfig(throttle=True),
                ExecutionConfig(bandwidth=1e6)):
        with pytest.raises(ValueError, match="process"):
            bad.resolve_backend()
    # ...and a process backend resolves configured
    be = ExecutionConfig(backend="process", payload_true=True,
                         bandwidth=2e6).resolve_backend()
    assert be.payload_true and be.throttle and be.bandwidth == 2e6


def test_execution_config_json_round_trip():
    from repro.serverless import faults as F
    from repro.serverless.backends import get_backend
    from repro.serverless.execution import ExecutionConfig

    ec = ExecutionConfig(
        backend="process", steps=3, trace=True, payload_true=True,
        bandwidth=1e6,
        faults=F.FaultPlan(events=(
            F.FaultEvent(kind="transient", stage=0, replica=0, step=0,
                         op="put", index=0),)),
        tolerance=F.FaultTolerance(retry=F.RetryPolicy(max_attempts=2)),
        checkpoint_every=2)
    again = ExecutionConfig.from_json(ec.to_json())
    assert again == ec
    with pytest.raises(ValueError, match="version"):
        ExecutionConfig.from_json(json.dumps({"version": 99}))
    with pytest.raises(ValueError, match="unknown"):
        ExecutionConfig.from_json(json.dumps({"version": 1, "surprise": 1}))
    # instance backends execute but do not serialize
    inst = ExecutionConfig(backend=get_backend("emulated"))
    with pytest.raises(TypeError, match="instance"):
        inst.to_json()


def test_emulate_legacy_kwargs_shim_bit_identical(bert_session):
    from repro.serverless.execution import ExecutionConfig

    plan = bert_session.deployment_plan
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = plan.emulate(steps=2)
    new = plan.emulate(ExecutionConfig(steps=2))
    assert legacy.t_iter == new.t_iter
    assert legacy.t_total == new.t_total
    assert legacy.store_stats.bytes_in == new.store_stats.bytes_in


def test_emulate_rejects_mixed_spellings(bert_session):
    from repro.serverless.execution import ExecutionConfig

    with pytest.raises(ValueError, match="not both"):
        bert_session.deployment_plan.emulate(ExecutionConfig(steps=1),
                                             steps=2)


def test_run_plan_legacy_shim_matches_config(bert_session):
    from repro.serverless.execution import ExecutionConfig

    rp = bert_session.deployment_plan.resolve()
    with pytest.warns(DeprecationWarning):
        legacy = run_plan(rp.profile, rp.platform, rp.config,
                          rp.total_micro_batches, steps=1,
                          pipelined_sync=rp.pipelined_sync)
    new = run_plan(rp.profile, rp.platform, rp.config,
                   rp.total_micro_batches, ExecutionConfig(steps=1),
                   pipelined_sync=rp.pipelined_sync)
    assert legacy.t_iter == new.t_iter
    assert legacy.cost == new.cost


def test_traced_emulate_embeds_plan_document(bert_session):
    from repro.serverless.execution import ExecutionConfig

    plan = bert_session.deployment_plan
    res = plan.emulate(ExecutionConfig(steps=1, trace=True))
    doc = res.trace.meta.get("plan")
    assert doc is not None
    assert DeploymentPlan.from_json(json.dumps(doc)) == plan
    # calibration-relevant metadata rides along
    assert res.trace.meta["t_lat"] == AWS_LAMBDA.storage_latency
    assert res.trace.meta["payload_true"] is False
