"""The boto3 S3 adapter (`backends/cloud.py`), tested hermetically against
an in-memory fake S3 client: round trips + byte accounting, blocking
visibility, retry policy on transient S3 codes, paginated key listing,
lease failover, and an end-to-end `run_plan` traffic-parity check — plus
the actionable open() failures when boto3/credentials/bucket are absent."""
import importlib.util
import io
import threading
import time

import pytest

from repro.serverless.backends import get_backend
from repro.serverless.backends.cloud import (
    AwsS3Backend,
    BackendUnavailableError,
    CloudConfig,
    S3ObjectStore,
)
from repro.serverless.retry import RetryPolicy
from repro.serverless.runtime.store import (
    ProducerDeadError,
    assert_store_drained,
)

HAVE_BOTO3 = importlib.util.find_spec("boto3") is not None


class FakeClientError(Exception):
    """botocore.exceptions.ClientError look-alike: carries .response."""

    def __init__(self, code, op="GetObject"):
        super().__init__(f"An error occurred ({code}) when calling {op}")
        self.response = {"Error": {"Code": code}}


class FakeS3Client:
    """In-memory boto3-S3-shaped client: put/get/delete/list_objects_v2
    with boto3's call and return shapes, optional scripted failures, and a
    small list page size so pagination is actually exercised."""

    def __init__(self, page_size=2):
        self.objects = {}
        self.page_size = page_size
        self.calls = []
        self._fail_queue = []           # (op, code) consumed FIFO
        self._lock = threading.Lock()

    def fail_next(self, op, code, times=1):
        with self._lock:
            self._fail_queue.extend((op, code) for _ in range(times))

    def _maybe_fail(self, op):
        with self._lock:
            if self._fail_queue and self._fail_queue[0][0] == op:
                _, code = self._fail_queue.pop(0)
                raise FakeClientError(code, op)

    def put_object(self, *, Bucket, Key, Body):
        self.calls.append(("put", Key))
        self._maybe_fail("put_object")
        with self._lock:
            self.objects[(Bucket, Key)] = bytes(Body)
        return {}

    def get_object(self, *, Bucket, Key):
        self.calls.append(("get", Key))
        self._maybe_fail("get_object")
        with self._lock:
            blob = self.objects.get((Bucket, Key))
        if blob is None:
            raise FakeClientError("NoSuchKey", "GetObject")
        return {"Body": io.BytesIO(blob)}

    def delete_object(self, *, Bucket, Key):
        self.calls.append(("delete", Key))
        self._maybe_fail("delete_object")
        with self._lock:
            self.objects.pop((Bucket, Key), None)
        return {}

    def list_objects_v2(self, *, Bucket, Prefix, ContinuationToken=None):
        with self._lock:
            keys = sorted(k for (b, k) in self.objects
                          if b == Bucket and k.startswith(Prefix))
        start = int(ContinuationToken or 0)
        page = keys[start:start + self.page_size]
        out = {"Contents": [{"Key": k} for k in page],
               "IsTruncated": start + self.page_size < len(keys)}
        if out["IsTruncated"]:
            out["NextContinuationToken"] = str(start + self.page_size)
        return out


def _store(client=None, **kw):
    cfg = CloudConfig(bucket="test-bucket", key_prefix="funcpipe/",
                      retry=RetryPolicy(max_attempts=4, base_delay_s=0.001))
    return S3ObjectStore(client or FakeS3Client(), cfg, **kw)


# ----------------------------------------------------------------- adapter
def test_round_trip_accounting_and_prefix():
    client = FakeS3Client()
    store = _store(client)
    store.put("k0/r0/m0/act0", 128.0, value={"a": 1})
    # objects land under the configured key prefix
    assert ("test-bucket", "funcpipe/k0/r0/m0/act0") in client.objects
    assert store.live_bytes == 128.0 and "k0/r0/m0/act0" in store
    value, nb = store.take("k0/r0/m0/act0", return_nbytes=True)
    assert value == {"a": 1} and nb == 128.0
    assert len(store) == 0 and store.live_bytes == 0.0
    assert store.stats.puts == store.stats.deletes == 1
    assert_store_drained(store)


def test_overwrite_counts_implicit_delete():
    store = _store()
    store.put("k", 100.0)
    store.put("k", 40.0)
    assert store.live_bytes == pytest.approx(40.0)
    store.delete("k")
    assert store.stats.puts == store.stats.deletes == 2
    assert store.stats.bytes_deleted == pytest.approx(store.stats.bytes_in)
    assert_store_drained(store)


def test_keys_paginate_across_list_calls():
    store = _store(FakeS3Client(page_size=2))
    want = [f"ckpt/s{i}" for i in range(5)]
    for k in want:
        store.put(k, 1.0)
    assert sorted(store.keys()) == sorted(want)


def test_transient_s3_codes_retry_per_policy():
    client = FakeS3Client()
    store = _store(client)
    client.fail_next("put_object", "SlowDown", times=2)
    store.put("k", 8.0, value="v")          # survives two throttles
    assert store.retried_ops == 2
    client.fail_next("get_object", "InternalError", times=1)
    assert store.take("k") == "v"
    assert store.retried_ops == 3


def test_retry_budget_exhaustion_surfaces_client_error():
    client = FakeS3Client()
    store = _store(client)
    client.fail_next("put_object", "SlowDown", times=10)
    with pytest.raises(FakeClientError, match="SlowDown"):
        store.put("k", 8.0)


def test_non_retryable_code_raises_immediately():
    client = FakeS3Client()
    store = _store(client)
    client.fail_next("put_object", "AccessDenied")
    with pytest.raises(FakeClientError, match="AccessDenied"):
        store.put("k", 8.0)
    assert store.retried_ops == 0


def test_blocking_get_waits_for_visibility():
    store = _store(timeout=10.0)
    got = {}

    def consumer():
        got["v"] = store.take("x")

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()
    store.put("x", 64.0, value="payload")
    t.join(timeout=10.0)
    assert got["v"] == "payload"


def test_get_timeout_diagnoses_missing_object():
    store = _store(timeout=0.05)
    with pytest.raises(TimeoutError, match="never became visible"):
        store.get("missing")


def test_dead_producer_fails_over_before_timeout():
    store = _store(timeout=30.0)
    store.mark_dead((0, 0))
    t0 = time.monotonic()
    with pytest.raises(ProducerDeadError, match="died"):
        store.get("k0/r0/m0/act0")      # produced by stage 0, replica 0
    assert time.monotonic() - t0 < 5.0


# ------------------------------------------------------------- end to end
def test_run_plan_traffic_parity_through_fake_s3():
    """The aws backend with an injected fake client moves exactly the same
    objects as the emulated backend, drained and conserved."""
    from test_backends import _timing_plan

    from repro.serverless.platform import AWS_LAMBDA
    from repro.serverless.runtime import run_plan

    prof, cfg = _timing_plan(d=2)
    be = AwsS3Backend(CloudConfig(bucket="test-bucket"),
                      client=FakeS3Client())
    aws = run_plan(prof, AWS_LAMBDA, cfg, 32, steps=2, pipelined_sync=True,
                   backend=be)
    em = run_plan(prof, AWS_LAMBDA, cfg, 32, steps=2, pipelined_sync=True,
                  backend="emulated")
    sa, se = aws.store_stats, em.store_stats
    assert (sa.puts, sa.gets, sa.deletes) == (se.puts, se.gets, se.deletes)
    assert sa.bytes_in == pytest.approx(se.bytes_in)
    assert aws.backend == "aws" and aws.wall_clock


# ----------------------------------------------------- unavailability paths
@pytest.mark.skipif(HAVE_BOTO3, reason="boto3 installed: open() proceeds")
def test_open_without_boto3_names_the_client():
    be = get_backend("aws")
    assert isinstance(be, AwsS3Backend)
    with pytest.raises(BackendUnavailableError, match="boto3"):
        be.open(None)


@pytest.mark.skipif(not HAVE_BOTO3, reason="needs boto3 for this branch")
def test_open_without_credentials_names_the_env_vars(monkeypatch):
    for var in ("AWS_ACCESS_KEY_ID", "AWS_SECRET_ACCESS_KEY"):
        monkeypatch.delenv(var, raising=False)
    with pytest.raises(BackendUnavailableError, match="AWS_ACCESS_KEY_ID"):
        get_backend("aws").open(None)


def test_missing_bucket_is_actionable():
    with pytest.raises(ValueError, match="bucket"):
        S3ObjectStore(FakeS3Client(), CloudConfig(bucket=""))
