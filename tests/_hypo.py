"""Optional-import shim for ``hypothesis``.

The property-based tests use hypothesis when it is installed (see
requirements-dev.txt); when it is absent the decorated tests are skipped at
collection time instead of erroring the whole module import.  Import from
here instead of from ``hypothesis`` directly:

    from _hypo import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Inert stand-in: strategy expressions built at module import time
        (e.g. ``st.lists(st.floats(...))``) must evaluate without error even
        though the skipped tests never run them."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()
