"""Model-level unit tests: decode-vs-forward consistency for every family,
recurrent-vs-parallel form equivalence, MoE routing invariants, blockwise
attention vs naive."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import get_config
from repro.configs.base import InputShape, LayerSpec
from repro.models import attention, mamba, moe, registry, xlstm
from repro.models.common import softmax_cross_entropy, vocab_parallel_cross_entropy


DECODABLE = ["phi3-mini-3.8b", "gemma3-4b", "qwen2.5-14b", "internlm2-20b",
             "internvl2-26b", "xlstm-125m", "jamba-v0.1-52b", "dbrx-132b",
             "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch_id", DECODABLE)
def test_prefill_decode_matches_forward(arch_id):
    cfg = get_config(arch_id).reduced()
    if cfg.moe is not None:  # no capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        from repro.models.multimodal import synth_patch_embeds
        batch["image_embeds"] = synth_patch_embeds(jax.random.PRNGKey(2), cfg, B)
    h, _ = registry.forward(cfg, params, {**batch, "labels": toks})
    ref_logits = registry._logits(cfg, params, h)
    pre = dict(batch)
    pre["tokens"] = toks[:, : S - 4]
    logits_last, caches = registry.prefill(cfg, params, pre, capacity=S)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(ref_logits[:, S - 5]),
        rtol=1e-4, atol=1e-4)
    for t in range(S - 4, S):
        sl, caches = registry.decode_step(cfg, params, caches, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(sl[:, 0]), np.asarray(ref_logits[:, t]),
            rtol=1e-4, atol=2e-4, err_msg=f"{arch_id} step {t}")


def test_mamba_parallel_vs_recurrent():
    cfg = get_config("jamba-v0.1-52b").reduced()
    p = mamba.init_mamba_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y_par, state = mamba.mamba_forward(p, x, cfg=cfg, return_state=True)
    cache = mamba.init_mamba_cache(B, cfg, cfg.mamba.d_inner(cfg.d_model), jnp.float32)
    ys = []
    for t in range(S):
        y, cache = mamba.mamba_decode(p, x[:, t:t + 1], cache, cfg=cfg)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state.h), np.asarray(cache.h), rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_vs_recurrent():
    cfg = get_config("xlstm-125m").reduced()
    p = xlstm.init_mlstm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y_par = xlstm.mlstm_forward(p, x, cfg=cfg)
    di = int(cfg.d_model * cfg.xlstm.m_proj_factor)
    cache = xlstm.init_mlstm_cache(B, cfg, di, cfg.n_heads, jnp.float32)
    ys = []
    for t in range(S):
        y, cache = xlstm.mlstm_decode(p, x[:, t:t + 1], cache, cfg=cfg)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec), rtol=3e-4, atol=3e-4)


def test_mlstm_chunk_boundary_invariance():
    """Chunked mLSTM result must not depend on the chunk size."""
    cfg = get_config("xlstm-125m").reduced()
    p = xlstm.init_mlstm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    import repro.models.xlstm as xm
    orig = xm.MLSTM_CHUNK
    try:
        xm.MLSTM_CHUNK = 8
        y8 = xlstm.mlstm_forward(p, x, cfg=cfg)
        xm.MLSTM_CHUNK = 32
        y32 = xlstm.mlstm_forward(p, x, cfg=cfg)
    finally:
        xm.MLSTM_CHUNK = orig
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=3e-4, atol=3e-4)


def test_blockwise_attention_matches_naive():
    from repro.models.attention import _blockwise_attention
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, hd = 2, 1024, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    from repro.kernels.ref import flash_attention_ref
    for window in [0, 128]:
        got = _blockwise_attention(q, k, v, pos, True, window)
        want = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_moe_no_drop_equals_dense_mixture():
    """With huge capacity, moe_forward == explicit per-token expert mixture."""
    cfg = get_config("dbrx-132b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    mc = cfg.moe
    p = moe.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    got, aux = moe.moe_forward(p, x, cfg=cfg)
    # explicit reference
    toks = x.reshape(-1, cfg.d_model)
    logits = toks @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, mc.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for t in range(toks.shape[0]):
        acc = jnp.zeros(cfg.d_model)
        for kk in range(mc.top_k):
            e = int(sel[t, kk])
            h = jax.nn.silu(toks[t] @ p["w_gate"][e]) * (toks[t] @ p["w_up"][e])
            acc = acc + gates[t, kk] * (h @ p["w_down"][e])
        outs.append(acc)
    want = jnp.stack(outs).reshape(B, S, cfg.d_model)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    cfg = get_config("dbrx-132b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    p = moe.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, _ = moe.moe_forward(p, x, cfg=cfg)
    assert np.all(np.isfinite(np.asarray(out)))


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_vocab_parallel_ce_matches(seed):
    key = jax.random.PRNGKey(seed)
    T, V = 8, 32
    logits = jax.random.normal(key, (T, V))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (T,), 0, V)
    want = softmax_cross_entropy(logits, labels)
    # single-shard vocab-parallel (identity psum) must agree
    got = vocab_parallel_cross_entropy(logits, labels, jnp.int32(0), V, lambda x: x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_window_cache_ring_semantics():
    """Decode with a ring cache == decode with a full cache, once warm."""
    cfg = get_config("gemma3-4b").reduced()
    spec_w = cfg.period[0]   # windowed layer spec (window=64 reduced)
    assert spec_w.window > 0
    p = attention.init_attn_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 48
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.arange(S, dtype=jnp.int32)
    full = attention.attn_forward(p, x, cfg=cfg, spec=spec_w, positions=pos)
    cache = attention.init_kv_cache(B, cfg.n_kv_heads,
                                    attention.cache_capacity(spec_w, S), cfg.hd,
                                    jnp.float32)
    outs = []
    for t in range(S):
        y, cache = attention.attn_decode(p, x[:, t:t + 1], cache, cfg=cfg, spec=spec_w)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)
