"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch runs one forward/train step (and one decode step where the
family supports decoding) on CPU; asserts output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch
from repro.models import registry
from repro.optim import SGD

TRAIN_SHAPE = InputShape("smoke_train", 64, 4, "train")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    cfg = get_config(arch_id).reduced()
    assert cfg.d_model <= 512
    assert cfg.n_layers <= max(2, cfg.period_len)
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, TRAIN_SHAPE)

    def loss_of(p):
        loss, m = registry.loss_fn(cfg, p, batch)
        return loss, m

    (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
    assert np.isfinite(float(loss)), arch_id
    assert float(loss) > 0
    # one SGD step moves the loss
    opt = SGD(lr=0.05, momentum=0.0)
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - 0.05 * g).astype(p.dtype), params, grads
    )
    loss2, _ = registry.loss_fn(cfg, new_params, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss), f"{arch_id}: step did not reduce loss"
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf))), arch_id


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS if not get_config(a).is_encoder])
def test_reduced_decode_step(arch_id):
    cfg = get_config(arch_id).reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, ctx = 2, 32
    caches = registry.init_decode_caches(cfg, B, ctx)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size, jnp.int32)
    logits, caches = registry.decode_step(cfg, params, caches, toks)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    # second step advances the cursor / state
    logits2, _ = registry.decode_step(cfg, params, caches, toks)
    assert np.all(np.isfinite(np.asarray(logits2)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_counts(arch_id):
    """The FULL configs (exercised via dry-run only) have sane param counts."""
    cfg = get_config(arch_id)
    n = cfg.param_count()
    expected = {
        "phi3-mini-3.8b": (3.0e9, 5.0e9),
        "hubert-xlarge": (0.7e9, 1.4e9),
        "qwen2.5-14b": (12e9, 17e9),
        "dbrx-132b": (110e9, 150e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "internlm2-20b": (17e9, 24e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "internvl2-26b": (17e9, 26e9),
        "gemma3-4b": (3.0e9, 5.5e9),
        "jamba-v0.1-52b": (45e9, 60e9),
    }[arch_id]
    assert expected[0] <= n <= expected[1], f"{arch_id}: {n/1e9:.2f}B"
