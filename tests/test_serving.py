"""Serving subsystem: pipelined decode parity, SLO planner, autoscaling.

The load-bearing claim is bit-identity: partitioned prefill + token-by-token
decode through the execution backends (KV caches round-tripping through the
object store every token) must emit exactly the tokens of the monolithic
single-process decode loop (:func:`repro.serving.reference_decode`).  The
SLO planner prefers a single stage for models this small — every extra
stage adds KV round-trips and boundary hops to each decoded token — so the
multi-stage path is exercised by forcing a 2-stage split of the planned
deployment.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.plan import DeploymentPlan, PlanCompatibilityError
from repro.api.session import InfeasiblePlanError, session
from repro.models import registry
from repro.serving import (
    InfeasibleSLOError,
    ServingSpec,
    arch_config_for_model,
    autoscale_plan,
    bursty_arrivals,
    estimate_serving,
    greedy_token,
    kv_bytes_per_instance,
    make_prompt,
    plan_serving,
    poisson_arrivals,
    reference_decode,
    run_serve_plan,
    simulate_replicas,
    trace_arrivals,
)

ARCHS = ["phi3-mini-3.8b@reduced", "qwen2.5-14b@reduced"]
BATCH, PREFILL, NEW = 2, 8, 3


@pytest.fixture(scope="module", params=ARCHS)
def served(request):
    """One planned serve deployment per arch + the monolithic oracle."""
    model = request.param
    plan = plan_serving(model, "aws", slo=60.0, batch=BATCH,
                        prefill_tokens=PREFILL, new_tokens=NEW)
    cfg = arch_config_for_model(model)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = make_prompt(cfg, BATCH, PREFILL, seed=0)
    ref = reference_decode(cfg, params, toks, NEW)
    return model, plan, cfg, ref


def _force_two_stages(plan):
    # cut after the embed instance (period_len=1 on the reduced archs, so
    # every profile-layer boundary is a legal stage cut)
    cuts = [0] * len(plan.x)
    cuts[1] = 1
    return dataclasses.replace(plan, x=tuple(cuts),
                               z=(0,) * (len(plan.x) + 1))


# ------------------------------------------------------------ decode parity
def test_planned_decode_parity_emulated(served):
    model, plan, cfg, ref = served
    res = run_serve_plan(plan, backend="emulated", seed=0)
    assert np.array_equal(res.tokens, ref), (res.tokens, ref)
    assert res.t_request > 0 and res.cost_per_request > 0
    assert res.store_stats.class_bytes_in.get("kv", 0) > 0
    assert res.kv_bytes and all(b > 0 for b in res.kv_bytes)


@pytest.mark.parametrize("backend", ["emulated", "process"])
def test_two_stage_decode_parity(served, backend, tmp_path):
    model, plan, cfg, ref = served
    plan2 = _force_two_stages(plan)
    kw = {"root": str(tmp_path)} if backend == "process" else {}
    res = run_serve_plan(plan2, backend=backend, seed=0, **kw)
    assert np.array_equal(res.tokens, ref), (model, backend)
    # both stages persisted KV through the store (verify_drained already ran
    # inside run_serve_plan: every boundary/token/kv key was consumed)
    assert res.store_stats.class_bytes_in.get("kv", 0) > 0
    assert len(res.kv_bytes) == 2 and all(b > 0 for b in res.kv_bytes)


def test_serve_phases_in_trace(served):
    model, plan, cfg, ref = served
    res = run_serve_plan(_force_two_stages(plan), backend="emulated",
                         seed=0, trace=True)
    phases = {s.phase for s in res.trace.spans}
    assert phases == {"prefill", "decode"}
    assert res.trace.meta["workload"] == "serve"


def test_unknown_backend_rejected(served):
    _, plan, _, _ = served
    with pytest.raises(ValueError, match="serving backend"):
        run_serve_plan(plan, backend="warp-drive")


# ------------------------------------------------------------------ planner
def test_planner_round_trip(tmp_path, served):
    model, plan, cfg, _ = served
    assert plan.workload == "serve"
    assert plan.serving["slo_s"] == 60.0
    assert plan.serving["t_request"] <= 60.0
    path = tmp_path / "serve_plan.json"
    plan.save(path)
    back = DeploymentPlan.load(path)
    assert back == plan
    assert back.content_hash == plan.content_hash
    rp = back.resolve()
    assert rp.config.x == plan.x
    # and the round-tripped plan still executes
    res = run_serve_plan(back, backend="emulated", seed=0)
    assert res.tokens.shape == (BATCH, NEW)


def test_train_plan_json_defaults_workload():
    # plans saved before the serving subsystem load as workload="train"
    plan = plan_serving(ARCHS[0], "aws", slo=60.0, batch=1,
                        prefill_tokens=4, new_tokens=2)
    doc = json.loads(plan.to_json())
    del doc["workload"], doc["serving"]
    old = DeploymentPlan.from_json(json.dumps(doc))
    assert old.workload == "train" and old.serving is None


def test_infeasible_slo_named_error():
    with pytest.raises(InfeasibleSLOError, match="SLO"):
        plan_serving(ARCHS[0], "aws", slo=1e-6, prefill_tokens=4,
                     new_tokens=2)
    # callers catching the planner's generic infeasibility still catch it
    assert issubclass(InfeasibleSLOError, InfeasiblePlanError)


def test_session_serve_front_door():
    s = session(ARCHS[0]).plan(workload="serve", slo=60.0, serve_batch=1,
                               prefill_tokens=4, new_tokens=2)
    plan = s.deployment_plan
    assert plan.workload == "serve" and plan.serving["batch"] == 1
    assert s.plan_result is None
    with pytest.raises(ValueError, match="slo"):
        session(ARCHS[0]).plan(workload="serve")
    with pytest.raises(ValueError, match="workload"):
        session(ARCHS[0]).plan(workload="batch-train")


def test_paper_models_rejected():
    with pytest.raises(KeyError, match="executable architecture"):
        plan_serving("bert-large", "aws", slo=60.0)


def test_serving_spec_validation():
    with pytest.raises(ValueError):
        ServingSpec(slo_s=0.0, batch=1, prefill_tokens=4, new_tokens=2)
    with pytest.raises(ValueError):
        ServingSpec(slo_s=1.0, batch=1, prefill_tokens=4, new_tokens=0)
    spec = ServingSpec(slo_s=1.0, batch=2, prefill_tokens=4, new_tokens=2)
    assert spec.s_ctx == 6


def test_estimate_counts_kv_in_memory(served):
    model, plan, cfg, _ = served
    spec = ServingSpec(slo_s=60.0, batch=BATCH, prefill_tokens=PREFILL,
                       new_tokens=NEW)
    kv = kv_bytes_per_instance(cfg, spec.batch, spec.s_ctx)
    assert kv > 0
    rp = plan.resolve()
    est = estimate_serving(rp.profile, rp.platform, rp.config, cfg, spec)
    assert est.kv_bytes and sum(est.kv_bytes) > 0
    assert est.t_request == pytest.approx(
        est.t_prefill + (NEW - 1) * est.t_token)


# ------------------------------------------------- workload guard rails
def test_training_entry_points_reject_serve_plans(served):
    _, plan, _, _ = served
    from repro.serverless.runtime import run_plan

    for call in (plan.evaluate, plan.simulate, plan.emulate,
                 lambda: run_plan(plan)):
        with pytest.raises(PlanCompatibilityError, match="serve"):
            call()


def test_serving_entry_points_reject_train_plans(served):
    _, plan, _, _ = served
    train_plan = dataclasses.replace(plan, workload="train", serving=None)
    with pytest.raises(PlanCompatibilityError, match="workload"):
        run_serve_plan(train_plan)
    with pytest.raises(PlanCompatibilityError, match="workload"):
        autoscale_plan(train_plan)


# -------------------------------------------------------------- autoscaling
def test_arrival_processes_deterministic():
    a = poisson_arrivals(2.0, 30.0, seed=7)
    b = poisson_arrivals(2.0, 30.0, seed=7)
    assert np.array_equal(a, b)
    assert len(a) and a[-1] < 30.0 and np.all(np.diff(a) >= 0)
    assert not np.array_equal(a, poisson_arrivals(2.0, 30.0, seed=8))
    c = bursty_arrivals(2.0, 30.0, seed=7)
    assert np.array_equal(c, bursty_arrivals(2.0, 30.0, seed=7))
    assert len(c) and c[-1] < 30.0


def test_trace_arrivals(tmp_path):
    p = tmp_path / "gaps.txt"
    p.write_text("# prod trace\n0.5\n0.25\n\n1.0\n")
    assert np.allclose(trace_arrivals(str(p)), [0.5, 0.75, 1.75])
    (tmp_path / "empty.txt").write_text("# nothing\n")
    with pytest.raises(ValueError, match="no inter-arrival"):
        trace_arrivals(str(tmp_path / "empty.txt"))


def test_simulate_replicas_queueing():
    arrivals = np.arange(10, dtype=np.float64)  # 1 req/s, back to back
    row = simulate_replicas(arrivals, replicas=2, t_request=1.5, slo_s=2.0,
                            mem_gb_total=1.0, price_per_gb_s=1e-4,
                            cold_start_s=0.0)
    assert row.requests == 10 and row.cold_starts == 2
    assert row.p50 >= 1.5 and 0.0 <= row.slo_violation_frac <= 1.0
    assert row.cost == pytest.approx(1e-4 * 1.0 * 10 * 1.5)
    # more replicas never increase tail latency on the same trace
    worse = simulate_replicas(arrivals, replicas=1, t_request=1.5, slo_s=2.0,
                              mem_gb_total=1.0, price_per_gb_s=1e-4,
                              cold_start_s=0.0)
    assert worse.p95 >= row.p95


def test_autoscale_plan_rows_deterministic(served):
    _, plan, _, _ = served
    kw = dict(rate=2.0, horizon=60.0, replicas=(1, 3), arrival="bursty",
              seed=3)
    rows = autoscale_plan(plan, **kw)
    again = autoscale_plan(plan, **kw)
    assert [r.as_dict() for r in rows] == [r.as_dict() for r in again]
    assert [r.replicas for r in rows] == [1, 3]
    assert all(r.requests == rows[0].requests for r in rows)


# -------------------------------------------------- pallas decode satellite
def test_pallas_decode_attention_parity():
    from repro.kernels import ref
    from repro.kernels.decode_attention import decode_attention

    key = jax.random.PRNGKey(3)
    B, Hq, Hkv, C, hd = 2, 4, 2, 32, 16
    q = jax.random.normal(key, (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, C, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, C, hd))
    out = decode_attention(q, k, v, jnp.int32(20), interpret=True)
    expect = ref.decode_attention_ref(q, k, v, jnp.int32(20))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_pallas_capability_probe():
    from repro.kernels import ops as kops

    ok = dict(n_q_heads=8, n_kv_heads=2, capacity=512)
    assert kops.decode_attention_capable(**ok)
    assert kops.decode_attention_capable(**{**ok, "capacity": 64})
    assert kops.decode_attention_capable(**{**ok, "capacity": 1024})
    assert not kops.decode_attention_capable(**{**ok, "capacity": 520})
    assert not kops.decode_attention_capable(**{**ok, "window": 128})
    assert not kops.decode_attention_capable(**{**ok, "seq_shards": 2})
    assert not kops.decode_attention_capable(
        n_q_heads=6, n_kv_heads=4, capacity=512)


def test_serve_with_pallas_decode(served, monkeypatch):
    # the wired decode path: capability-probed Pallas attention per layer
    # (interpret mode on CPU), same greedy tokens as the jnp path
    model, plan, cfg, ref = served
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    res = run_serve_plan(plan, backend="emulated", seed=0, use_pallas=True)
    assert np.array_equal(res.tokens, ref)


# ------------------------------------------- mesh-pipelined serve_equiv
@pytest.mark.skipif(not hasattr(jax, "set_mesh"),
                    reason="jax.set_mesh not available in this jax")
def test_serve_equiv_module():
    from repro.testing import serve_equiv

    assert serve_equiv.run("phi3-mini-3.8b", stages=2, tensor=1,
                           seq_shards=1, n_decode=2)


# ------------------------------------------------------------ worker pieces
def test_greedy_token_rule():
    logits = np.zeros((2, 3, 5), np.float32)
    logits[0, -1, 4] = 1.0
    logits[1, -1, 2] = 1.0
    tok = greedy_token(logits)
    assert tok.shape == (2, 1) and tok.dtype == np.int32
    assert tok[0, 0] == 4 and tok[1, 0] == 2
