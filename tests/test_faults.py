"""Chaos harness + fault-tolerant engine: FaultPlan determinism and JSON
round-trips, bit-identical recovery through transients/crashes/lifetime caps
on all backends (including real SIGKILL'd worker processes on ``process``)
and both sync schedules, retry exhaustion, checkpoint wire hardening,
LocalStore leases/heartbeats, and recovery observability."""
import json
import os
import threading
import time

import numpy as np
import pytest

from test_backends import _assert_bit_identical, _numeric_setup, _timing_plan

from repro.serverless import faults as F
from repro.serverless.backends.local import LocalStore
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.runtime import run_plan
from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
)

jax = pytest.importorskip("jax")


# --------------------------------------------------------------- fault plans
def test_fault_plan_generation_is_deterministic():
    kw = dict(steps=4, S=3, d=2, n_transient=3, n_crashes=2, n_stragglers=1,
              lifetime_steps=3)
    a = F.FaultPlan.generate(11, **kw)
    b = F.FaultPlan.generate(11, **kw)
    assert a == b
    assert a.counts() == {"transient": 3, "crash": 2, "straggle": 1,
                          "lifetime_steps": 3}
    # a different seed reshuffles the schedule (same shape)
    c = F.FaultPlan.generate(12, **kw)
    assert c != a and c.counts() == a.counts()


def test_fault_plan_json_round_trip(tmp_path):
    plan = F.FaultPlan.generate(5, steps=3, S=2, d=2, n_stragglers=1,
                                lifetime_steps=2)
    assert F.FaultPlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    plan.save(path)
    assert F.FaultPlan.load(path) == plan
    # the file is plain JSON a human can edit
    doc = json.loads(path.read_text())
    assert doc["version"] == 1 and doc["seed"] == 5


def test_fault_plan_rejects_unknown_fields_and_versions():
    with pytest.raises(ValueError, match="unknown FaultEvent fields"):
        F.FaultEvent.from_dict({"kind": "crash", "stage": 0, "replica": 0,
                                "step": 0, "flavor": "spicy"})
    with pytest.raises(ValueError, match="version 1"):
        F.FaultPlan.from_json('{"version": 2, "events": []}')
    with pytest.raises(ValueError, match="version 1"):
        F.FaultPlan.from_json('[1, 2]')


def test_retry_policy_backoff_is_deterministic_and_capped():
    pol = F.RetryPolicy(max_attempts=4, base_delay_s=0.05, multiplier=2.0,
                        max_delay_s=0.12, jitter=0.25)
    d1 = [pol.delay(a, "k0/r0/m0/act0") for a in (1, 2, 3)]
    d2 = [pol.delay(a, "k0/r0/m0/act0") for a in (1, 2, 3)]
    assert d1 == d2                                   # pure function
    assert all(d <= 0.12 * 1.25 + 1e-12 for d in d1)  # cap (+jitter)
    assert pol.delay(1, "other-key") != d1[0]          # token-jittered
    assert F.RetryPolicy(jitter=0.0).delay(3) == pytest.approx(0.2)


# -------------------------------------------------- chaos parity (numerics)
def _chaos_plan():
    """Hand-built schedule covering every recovery path: a transient put, a
    transient get, a mid-bwd crash, and a 2-step function-lifetime cap."""
    return F.FaultPlan(events=(
        F.FaultEvent(kind="transient", stage=0, replica=0, step=0,
                     op="put", index=0),
        F.FaultEvent(kind="transient", stage=1, replica=1, step=1,
                     op="get", index=1),
        F.FaultEvent(kind="crash", stage=1, replica=0, step=1, phase="bwd"),
    ), lifetime_steps=2, seed=None)


_REFERENCE = {}


def _fault_free_params(pipelined):
    """Fault-free reference params (cached; emulated — backend parity of the
    clean run is test_backends' business)."""
    if pipelined not in _REFERENCE:
        _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=3)
        res = run_plan(prof, AWS_LAMBDA, config, 4, steps=3,
                       pipelined_sync=pipelined, execution=mk_exec(),
                       backend="emulated")
        _REFERENCE[pipelined] = res.params
    return _REFERENCE[pipelined]


@pytest.mark.parametrize("backend", ["emulated", "local", "process"])
@pytest.mark.parametrize("pipelined", [True, False],
                         ids=["eq2-pipelined", "eq1-three-phase"])
def test_chaos_run_recovers_bit_identical(backend, pipelined):
    """Training through transients + a crash + a lifetime cap must land on
    exactly the fault-free params — recovery replays from store checkpoints
    and replayed programs are idempotent over store keys.  On the process
    backend the injected crash SIGKILLs a real OS worker process."""
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=3)
    res = run_plan(prof, AWS_LAMBDA, config, 4, steps=3,
                   pipelined_sync=pipelined, execution=mk_exec(),
                   backend=backend, faults=_chaos_plan(),
                   tolerance=F.FaultTolerance(
                       retry=F.RetryPolicy(base_delay_s=0.01),
                       # force the injector's lifetime kill (not only the
                       # Function Manager's planned restarts) to exercise
                       # the crash-recovery path for the cap too
                       lifetime_safety=0.9))
    rep = res.fault_report
    assert rep is not None
    assert rep.injected.get("transient", 0) >= 1
    assert rep.injected.get("crash", 0) >= 1
    assert rep.retries >= 1
    assert rep.restarts + rep.planned_restarts >= 2   # crash + lifetime cap
    assert rep.checkpoints >= 1
    _assert_bit_identical(res.params, _fault_free_params(pipelined))
    # losses replayed identically too (run_plan verified drained internally)
    assert [m["loss"] for m in res.metrics] == pytest.approx(
        [6.9599, 6.6724, 4.5243], abs=1e-3)


def test_chaos_report_identical_across_backends():
    """The injection schedule is deterministic per worker per step, so both
    backends see the *same* faults — not just the same final params."""
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=3)
    reports = {}
    for name in ("emulated", "local"):
        res = run_plan(prof, AWS_LAMBDA, config, 4, steps=3,
                       pipelined_sync=True, execution=mk_exec(),
                       backend=name, faults=_chaos_plan(),
                       tolerance=F.FaultTolerance(
                           retry=F.RetryPolicy(base_delay_s=0.01)))
        reports[name] = res.fault_report
    em, lo = reports["emulated"], reports["local"]
    assert em.injected == lo.injected
    assert em.retries == lo.retries
    assert em.checkpoints == lo.checkpoints
    assert em.resumed_steps == lo.resumed_steps


def test_execution_tolerance_field_enables_recovery():
    """``Execution.tolerance`` is an alternative to the run_plan kwarg."""
    import dataclasses

    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=2)
    ex = dataclasses.replace(mk_exec(), tolerance=F.FaultTolerance(
        retry=F.RetryPolicy(base_delay_s=0.01)))
    plan = F.FaultPlan(events=(
        F.FaultEvent(kind="transient", stage=0, replica=1, step=0,
                     op="get", index=0),))
    res = run_plan(prof, AWS_LAMBDA, config, 4, steps=2,
                   pipelined_sync=True, execution=ex, backend="emulated",
                   faults=plan)
    assert res.fault_report.retries == 1
    ref = run_plan(prof, AWS_LAMBDA, config, 4, steps=2,
                   pipelined_sync=True, execution=mk_exec(),
                   backend="emulated")
    _assert_bit_identical(res.params, ref.params)


# ----------------------------------------------------- budgets + exhaustion
def test_retry_exhaustion_raises_typed_error():
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=2)
    plan = F.FaultPlan(events=(
        F.FaultEvent(kind="transient", stage=0, replica=0, step=0,
                     op="put", index=0, times=10),))
    with pytest.raises(F.FaultToleranceExceeded, match="still failing"):
        run_plan(prof, AWS_LAMBDA, config, 4, steps=2, pipelined_sync=True,
                 execution=mk_exec(), backend="emulated", faults=plan,
                 tolerance=F.FaultTolerance(
                     retry=F.RetryPolicy(max_attempts=3,
                                         base_delay_s=0.001)))


def test_restart_budget_exhaustion_raises_typed_error():
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=2)
    # one crash per step/phase, far more than the restart budget
    events = tuple(
        F.FaultEvent(kind="crash", stage=0, replica=0, step=k, phase=ph)
        for k in range(2) for ph in ("fwd", "bwd"))
    with pytest.raises(F.FaultToleranceExceeded, match="max_restarts"):
        run_plan(prof, AWS_LAMBDA, config, 4, steps=2, pipelined_sync=True,
                 execution=mk_exec(), backend="emulated",
                 faults=F.FaultPlan(events=events),
                 tolerance=F.FaultTolerance(max_restarts=2))


def test_faults_without_tolerance_use_default_recovery():
    """Injecting faults implies a default FaultTolerance — chaos runs should
    not need recovery boilerplate to terminate."""
    prof, cfg = _timing_plan(d=2)
    res = run_plan(prof, AWS_LAMBDA, cfg, 8, steps=2, pipelined_sync=True,
                   backend="emulated",
                   faults=F.FaultPlan(events=(
                       F.FaultEvent(kind="crash", stage=1, replica=0,
                                    step=1, phase="fwd"),)))
    assert res.fault_report.restarts == 1
    assert res.fault_report.resumed_steps == [1]


def test_checkpoint_restart_resumes_from_correct_step():
    """checkpoint_every=2 over 4 steps: a crash in step 3 must resume from
    step 2 (state-after-step-1 checkpoint), replaying steps 2 and 3."""
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=4)
    plan = F.FaultPlan(events=(
        F.FaultEvent(kind="crash", stage=0, replica=1, step=3, phase="fwd"),))
    res = run_plan(prof, AWS_LAMBDA, config, 4, steps=4,
                   pipelined_sync=True, execution=mk_exec(),
                   backend="emulated", faults=plan,
                   tolerance=F.FaultTolerance(checkpoint_every=2))
    rep = res.fault_report
    assert rep.restarts == 1 and rep.resumed_steps == [2]
    assert rep.checkpoints >= 1
    ref = run_plan(prof, AWS_LAMBDA, config, 4, steps=4,
                   pipelined_sync=True, execution=mk_exec(),
                   backend="emulated")
    _assert_bit_identical(res.params, ref.params)


def test_straggler_slows_but_does_not_change_numbers():
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=3)
    plan = F.FaultPlan(events=(
        F.FaultEvent(kind="straggle", stage=0, replica=0, step=0,
                     slow_s=0.5),))
    res = run_plan(prof, AWS_LAMBDA, config, 4, steps=3,
                   pipelined_sync=True, execution=mk_exec(),
                   backend="emulated", faults=plan)
    assert res.fault_report.injected == {"straggle": 1}
    assert res.fault_report.restarts == 0
    _assert_bit_identical(res.params, _fault_free_params(True))


# -------------------------------------------------- recovery observability
def test_traced_chaos_run_validates_and_reports_recovery():
    from repro.obs import pipeline_health, validate_trace

    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=3)
    res = run_plan(prof, AWS_LAMBDA, config, 4, steps=3,
                   pipelined_sync=True, execution=mk_exec(),
                   backend="emulated", trace=True, faults=_chaos_plan(),
                   tolerance=F.FaultTolerance(
                       retry=F.RetryPolicy(base_delay_s=0.01)))
    validate_trace(res.trace)                  # replays stay schema-valid
    assert res.trace.meta["fault_report"] == res.fault_report.as_dict()
    h = pipeline_health(res.trace)
    rcv = h["recovery"]
    assert rcv["retry_count"] >= 1 and rcv["retry_s"] > 0.0
    assert rcv["restart_count"] >= 1 and rcv["restart_bytes"] > 0.0
    rec = h["reconciliation"]
    assert rec["ok"], rec                      # bytes still conserved


def test_chaos_timing_run_charges_recovery_on_virtual_clock():
    prof, cfg = _timing_plan(d=2)
    base = run_plan(prof, AWS_LAMBDA, cfg, 8, steps=2, pipelined_sync=True,
                    backend="emulated")
    chaos = run_plan(prof, AWS_LAMBDA, cfg, 8, steps=2, pipelined_sync=True,
                     backend="emulated", faults=_chaos_plan())
    assert chaos.fault_report.count_injected is not None
    assert chaos.t_iter > base.t_iter          # recovery is not free
    assert chaos.fault_report.recovery_s > 0.0


# -------------------------------------------------------- checkpoint wire
def test_ckpt_pack_unpack_round_trip():
    from repro.checkpoint import pack_state, unpack_state

    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.float64(2.5)}
    blob = pack_state(tree, step=7)
    out, step = unpack_state(blob, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


@pytest.mark.parametrize("mutate, match", [
    (lambda t: {"other": t["w"]}, "treedef"),
    (lambda t: {"w": t["w"].astype(np.float64), "b": t["b"]}, "dtype"),
    (lambda t: {"w": t["w"][:1], "b": t["b"]}, "shape"),
], ids=["treedef", "dtype", "shape"])
def test_ckpt_restore_validates_structure(mutate, match):
    from repro.checkpoint import CheckpointError, pack_state, unpack_state

    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros((), np.float32)}
    blob = pack_state(mutate(tree))
    with pytest.raises(CheckpointError, match=match):
        unpack_state(blob, tree)


def test_ckpt_rejects_garbage_payloads():
    from repro.checkpoint import CheckpointError, unpack_state

    with pytest.raises(CheckpointError, match="msgpack"):
        unpack_state(b"\xc1 definitely not msgpack", {"w": np.zeros(2)})
    import msgpack

    with pytest.raises(CheckpointError, match="leaves"):
        unpack_state(msgpack.packb({"step": 1}), {"w": np.zeros(2)})


def test_ckpt_atomic_write_survives_crash(tmp_path, monkeypatch):
    """A crash mid-save (simulated by failing the final rename) leaves the
    previous checkpoint intact — a truncated .tmp never shadows it."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    path = str(tmp_path / "state.ckpt")
    v1 = {"w": np.full((3,), 1.0, np.float32)}
    save_checkpoint(path, v1, step=1)

    real_replace = os.replace

    def crash_replace(src, dst):
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(os, "replace", crash_replace)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(path, {"w": np.full((3,), 2.0, np.float32)}, step=2)
    monkeypatch.setattr(os, "replace", real_replace)

    tree, step = restore_checkpoint(path, v1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), v1["w"])


# --------------------------------------------------- LocalStore leases
def test_local_store_dead_producer_fails_fast():
    store = LocalStore(timeout=30.0, lease_timeout=1.0)
    store.heartbeat((0, 0))
    store.mark_dead((0, 0))
    t0 = time.monotonic()
    with pytest.raises(ProducerDeadError, match="died"):
        store.get("k0/r0/m0/act0")             # produced by (0, 0)
    assert time.monotonic() - t0 < 5.0         # far under the get timeout


def test_local_store_stale_heartbeat_fails_fast():
    store = LocalStore(timeout=30.0, lease_timeout=0.2)
    store.heartbeat((1, 0))
    time.sleep(0.4)
    t0 = time.monotonic()
    # stage s+1 produces grad{s}: "k0/r0/m0/grad0" comes from worker (1, 0)
    with pytest.raises(ProducerDeadError, match="stopped heartbeating"):
        store.get("k0/r0/m0/grad0")
    assert time.monotonic() - t0 < 5.0


def test_local_store_abort_wakes_blocked_consumers():
    store = LocalStore(timeout=30.0)
    errs = []

    def consumer():
        try:
            store.get("k0/sync0/part/0/1")
        except BaseException as e:             # noqa: BLE001 - test capture
            errs.append(e)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    store.abort(RuntimeError("worker exploded"))
    t.join(timeout=10.0)
    assert len(errs) == 1 and isinstance(errs[0], StoreAbortedError)
    assert "worker exploded" in str(errs[0])
    # revive() clears the poison for the next launch
    store.revive()
    store.put("x", 1.0, value=1)
    assert store.get("x") == 1


def test_local_store_timeout_diagnostic_names_the_suspect():
    store = LocalStore(timeout=0.1, lease_timeout=10.0)
    store.put("k0/r0/m0/act0", 8.0, value=b"x")
    store.heartbeat((1, 1))
    with pytest.raises(TimeoutError) as ei:
        store.get("k0/r1/m0/act1")             # producer (1, 1), never put
    msg = str(ei.value)
    assert "never became visible" in msg
    assert "stage 1, replica 1" in msg         # lease holder named
    assert "last heartbeat" in msg
    assert "k0/r0/m0/act0" in msg              # existing keys sampled
