"""Storage-backed execution engine: store semantics, scatter-reduce numerics
and timing vs eq (1)/(2), engine timing vs the analytic simulator, and K-step
numeric equivalence vs the monolithic training path."""
import dataclasses

import numpy as np
import pytest

from repro.core.partition import merge_layers
from repro.core.perfmodel import (
    Config,
    sync_time_nonpipelined,
    sync_time_pipelined,
)
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA, MB
from repro.serverless.runtime import (
    Execution,
    ObjectStore,
    StageChannel,
    pipelined_scatter_reduce,
    run_plan,
    stage_instance_ranges,
    three_phase_scatter_reduce,
)
from repro.serverless.simulator import simulate_funcpipe


# ----------------------------------------------------------------- the store
def test_store_charges_bandwidth_latency_and_visibility():
    store = ObjectStore(latency=0.1)
    a = StageChannel(store, bandwidth=100.0, latency=0.1, name="a")
    b = StageChannel(store, bandwidth=50.0, latency=0.1, name="b")

    end = a.upload("x", nbytes=200.0, ready=1.0, value="payload")
    assert end == pytest.approx(1.0 + 200.0 / 100.0 + 0.1)
    assert store.head("x").visible_at == pytest.approx(end)

    # download can't start before the object is visible; downloader's own
    # bandwidth applies to the producer's bytes
    val, t = b.download("x", ready=0.0)
    assert val == "payload"
    assert t == pytest.approx(end + 200.0 / 50.0 + 0.1)

    # uplink serializes; a continuation request skips the round-trip
    e2 = a.upload("y", nbytes=100.0, ready=0.0, new_request=False)
    assert e2 == pytest.approx(end + 100.0 / 100.0)

    store.delete("x")
    assert "x" not in store and "y" in store
    assert store.stats.puts == 2 and store.stats.gets == 1


def test_effective_bandwidth_shares_contention_model():
    from repro.serverless.runtime import effective_bandwidth
    from repro.serverless.simulator import bandwidth_contention, storage_capped_bw

    mem = ALIBABA_FC.memory_options[-1]
    for n in (1, 8, 32):
        got = effective_bandwidth(ALIBABA_FC, mem, n, contention=True)
        want = storage_capped_bw(
            ALIBABA_FC, ALIBABA_FC.bandwidth(mem) * bandwidth_contention(n), n)
        assert got == pytest.approx(want)
    # AWS S3 is uncapped; Alibaba OSS caps total storage bandwidth (§5.7)
    assert effective_bandwidth(AWS_LAMBDA, AWS_LAMBDA.memory_options[-1], 64) \
        == AWS_LAMBDA.bandwidth(AWS_LAMBDA.memory_options[-1])
    assert effective_bandwidth(ALIBABA_FC, mem, 64) < ALIBABA_FC.bandwidth(mem)


def _channels(n, w=70 * MB, lat=0.04):
    store = ObjectStore(lat)
    return store, [StageChannel(store, w, lat, name=f"w{r}") for r in range(n)]


# ------------------------------------------------------------- scatter-reduce
@pytest.mark.parametrize("algo", [pipelined_scatter_reduce,
                                  three_phase_scatter_reduce])
def test_scatter_reduce_matches_plain_sum(algo):
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    n = 4
    vals = [rng.normal(size=1003).astype(np.float32) for _ in range(n)]
    store, chans = _channels(n, w=1e8, lat=0.01)
    reduced, ends = algo(store, chans, nbytes=1003 * 4, ready=[0.0] * n,
                         values=vals)
    expect = np.asarray(jnp.sum(jnp.stack(vals), axis=0))
    np.testing.assert_allclose(reduced, expect, atol=1e-5)
    assert len(ends) == n and all(e > 0 for e in ends)


def test_three_phase_lands_on_eq1():
    s = 200 * MB
    for n in (2, 4, 8):
        store, chans = _channels(n)
        _, ends = three_phase_scatter_reduce(store, chans, s, [0.0] * n)
        eq1 = sync_time_nonpipelined(s, 70 * MB, n, 0.04)
        assert max(ends) == pytest.approx(eq1, rel=1e-9)


def test_pipelined_beats_three_phase_and_tracks_eq2():
    s = 200 * MB
    for n in (4, 8, 16):
        store, chans = _channels(n)
        _, ends3 = three_phase_scatter_reduce(store, chans, s, [0.0] * n)
        store, chans = _channels(n)
        _, endsp = pipelined_scatter_reduce(store, chans, s, [0.0] * n)
        eq2 = sync_time_pipelined(s, 70 * MB, n, 0.04)
        assert max(endsp) < max(ends3), n
        assert abs(max(endsp) - eq2) / eq2 < 0.12, n


# --------------------------------------------------------- engine vs simulator
@pytest.mark.parametrize("platform,d,M", [
    (AWS_LAMBDA, 1, 16),
    (AWS_LAMBDA, 4, 64),
    (ALIBABA_FC, 2, 32),
])
def test_engine_t_iter_tracks_simulator(platform, d, M):
    prof = merge_layers(paper_model_profile("bert-large", platform), 8)
    L = prof.L
    x = tuple(1 if i in (1, 3, 5) else 0 for i in range(L - 1))
    j = len(platform.memory_options) - 2
    cfg = Config(x=x, d=d, z=tuple(j for _ in range(L)))
    sim = simulate_funcpipe(prof, platform, cfg, M)
    eng = run_plan(prof, platform, cfg, M, steps=2)
    assert eng.n_workers == sim.n_workers
    assert eng.t_iter == pytest.approx(sim.t_iter, rel=0.15)
    # storage traffic actually flowed: 2 boundaries x (act + grad) x mu x d
    assert eng.store_stats.puts > 0


def test_engine_nonpipelined_sync_is_slower():
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    cfg = Config(x=x, d=8, z=tuple(5 for _ in range(L)))
    fast = run_plan(prof, AWS_LAMBDA, cfg, 64, pipelined_sync=True)
    slow = run_plan(prof, AWS_LAMBDA, cfg, 64, pipelined_sync=False)
    assert fast.breakdown["sync"] < slow.breakdown["sync"]
    assert fast.t_iter < slow.t_iter


# --------------------------------------------------------------- stage spans
def test_stage_instance_ranges_mapping():
    import repro.configs as configs

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=4)
    L = cfg.n_layers + 2
    # [embed, l0, l1 | l2, l3, head]
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    spans = stage_instance_ranges(cfg, x)
    assert [(s.inst_lo, s.inst_hi) for s in spans] == [(0, 2), (2, 4)]
    assert spans[0].owns_embed and not spans[0].owns_head
    assert spans[1].owns_head and not spans[1].owns_embed

    with pytest.raises(ValueError):
        stage_instance_ranges(cfg, tuple([1] + [0] * (L - 3)))  # wrong length


def test_stage_instance_ranges_rejects_mid_period_cut():
    import repro.configs as configs

    cfg = configs.get_config("jamba-v0.1-52b").reduced()  # period_len > 1
    if cfg.period_len == 1:
        pytest.skip("family reduced to period_len 1")
    L = cfg.n_layers + 2
    x = [0] * (L - 1)
    x[1] = 1  # cut after layer 0: mid-period
    with pytest.raises(ValueError):
        stage_instance_ranges(cfg, tuple(x))


# ------------------------------------------------- end-to-end numeric training
def _reference_loop(cfg, params, batches, optimizer, steps):
    """Monolithic single-device fp32-master loop (same math as the engine)."""
    import jax
    import jax.numpy as jnp

    from repro.models import registry

    masters = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    states = jax.tree.map(lambda m: optimizer.init_state(m), masters)
    losses = []
    for k in range(steps):
        (loss, _), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(cfg, p, batches[k]), has_aux=True)(params)
        losses.append(float(loss))
        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(masters)
        flat_s = jax.tree.leaves(
            states, is_leaf=lambda v: isinstance(v, dict) and v.keys() and all(
                not isinstance(x, dict) for x in v.values()))
        outs = [optimizer.update(g.astype(jnp.float32), m, s,
                                 jnp.asarray(k, jnp.int32))
                for g, m, s in zip(flat_g, flat_m, flat_s)]
        masters = jax.tree.unflatten(tdef, [a for a, _ in outs])
        states = jax.tree.unflatten(tdef, [b for _, b in outs])
        params = jax.tree.map(lambda m, p: m.astype(p.dtype), masters, params)
    return params, losses


def _param_err(a_tree, b_tree):
    import jax
    import jax.numpy as jnp
    from jax.tree_util import keystr, tree_leaves_with_path

    ref = {keystr(p): l for p, l in tree_leaves_with_path(b_tree)}
    worst = ("", 0.0)
    for pth, a in tree_leaves_with_path(a_tree):
        e = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - ref[keystr(pth)].astype(jnp.float32))))
        if e > worst[1]:
            worst = (keystr(pth), e)
    return worst


def test_engine_two_steps_match_monolithic():
    """Acceptance: K=2 storage-backed steps == monolithic loop (fp32), and
    the engine's simulated t_iter agrees with simulate_funcpipe."""
    import jax

    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=4)
    B, S, d, mu, steps = 8, 16, 2, 2, 2
    shape = InputShape("emu", S, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=S, micro_batch=B // (d * mu))
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    config = Config(x=x, d=d, z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
    optimizer = AdamW(lr=1e-2)
    batches = [make_batch(cfg, shape, step=k) for k in range(steps)]

    res = run_plan(
        prof, AWS_LAMBDA, config, total_micro_batches=d * mu, steps=steps,
        execution=Execution(cfg=cfg, optimizer=optimizer, init_params=params0,
                            batch_fn=lambda k: batches[k]))
    ref_params, ref_losses = _reference_loop(cfg, params0, batches, optimizer,
                                             steps)

    for got, want in zip(res.losses, ref_losses):
        assert abs(got - want) < 2e-4, (got, want)
    name, err = _param_err(res.params, ref_params)
    # fp32 summation-order noise through Adam's g/|g| normalization
    assert err < 2e-3, (name, err)

    sim = simulate_funcpipe(prof, AWS_LAMBDA, config, d * mu)
    assert res.t_iter == pytest.approx(sim.t_iter, rel=0.15)


def test_engine_single_stage_sgd_is_tight():
    """S=1, d=2: pure scatter-reduce path; SGD keeps the comparison linear,
    so the match is near machine precision."""
    import jax

    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import SGD

    cfg = configs.get_config("phi3-mini-3.8b").reduced()  # 2 layers
    B, S = 8, 16
    shape = InputShape("emu1", S, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=S, micro_batch=2)
    L = prof.L
    config = Config(x=tuple(0 for _ in range(L - 1)), d=2,
                    z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(1))
    optimizer = SGD(lr=0.05)
    batches = [make_batch(cfg, shape, seed=1, step=0)]

    res = run_plan(
        prof, AWS_LAMBDA, config, total_micro_batches=4, steps=1,
        execution=Execution(cfg=cfg, optimizer=optimizer, init_params=params0,
                            batch_fn=lambda k: batches[k]))
    ref_params, ref_losses = _reference_loop(cfg, params0, batches, optimizer, 1)
    assert abs(res.losses[0] - ref_losses[0]) < 5e-5
    name, err = _param_err(res.params, ref_params)
    assert err < 1e-4, (name, err)
