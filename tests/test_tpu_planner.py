"""TPU co-planner (the pod adaptation of the paper's MIQP)."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core import tpu_planner


@pytest.mark.parametrize("arch_id", ["phi3-mini-3.8b", "gemma3-4b",
                                     "qwen3-moe-235b-a22b", "xlstm-125m",
                                     "jamba-v0.1-52b"])
def test_feasible_plans_exist(arch_id):
    cfg = get_config(arch_id)
    res = tpu_planner.solve(cfg, INPUT_SHAPES["train_4k"])
    assert res, arch_id
    best = res[0]
    assert best.hbm_est <= tpu_planner.HBM_BYTES
    assert best.plan.stages * best.plan.tensor == 16
    assert best.t_step_est > 0


def test_objective_orders_results():
    cfg = get_config("phi3-mini-3.8b")
    res = tpu_planner.solve(cfg, INPUT_SHAPES["train_4k"], alpha=(0.0, 1.0))
    objs = [r.objective for r in res]
    assert objs == sorted(objs)


def test_memory_constraint_prunes():
    """qwen3-235B with remat=none at deep TP must never exceed HBM."""
    cfg = get_config("qwen3-moe-235b-a22b")
    res = tpu_planner.solve(cfg, INPUT_SHAPES["train_4k"])
    for r in res:
        assert r.hbm_est <= tpu_planner.HBM_BYTES


def test_planner_agrees_with_hillclimb_direction():
    """The planner independently prefers the configurations the §Perf
    hillclimb found (S=8/tp=2 over the S=2/tp=8 default for gemma3)."""
    cfg = get_config("gemma3-4b")
    res = tpu_planner.solve(cfg, INPUT_SHAPES["train_4k"], alpha=(0.0, 1.0))
    best = res[0].plan
    default = next(r for r in res
                   if r.plan.stages == cfg.stages and r.plan.tensor == cfg.tensor
                   and r.plan.remat == "tick")
    assert res[0].t_step_est < default.t_step_est
    assert best.stages >= 4  # moves away from tp-heavy default
