"""Execution-backend API: registry semantics, emulated/local/process parity
(store traffic, byte conservation, and bit-identical K-step training under
real thread *and* real process concurrency), the wall-clock LocalStore's
blocking visibility, and the saved-plan -> ``emulate --backend local`` CLI
round trip."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from test_runtime import _param_err, _reference_loop

from repro.core.partition import merge_layers
from repro.core.perfmodel import Config
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.backends import (
    EmulatedBackend,
    ExecutionBackend,
    LocalBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.serverless.backends.local import LocalStore
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.runtime import Execution, run_plan

jax = pytest.importorskip("jax")


# ----------------------------------------------------------------- registry
def test_registry_resolves_names_and_instances():
    assert {"emulated", "local", "process", "aws",
            "oss"} <= set(available_backends())
    be = get_backend("emulated")
    assert isinstance(be, EmulatedBackend) and not be.wall_clock
    lo = get_backend("local")
    assert isinstance(lo, LocalBackend) and lo.wall_clock
    # a pre-configured instance passes through untouched
    mine = LocalBackend(get_timeout=5.0)
    assert get_backend(mine) is mine
    # fresh instance per name lookup (no shared store state across runs)
    assert get_backend("emulated") is not be

    with pytest.raises(KeyError, match="unknown execution backend"):
        get_backend("s3-but-misspelled")

    class Custom(EmulatedBackend):
        name = "custom-test"

    register_backend("custom-test", Custom)
    try:
        assert isinstance(get_backend("custom-test"), Custom)
    finally:
        from repro.serverless import backends as _b

        _b._REGISTRY.pop("custom-test", None)


def test_cloud_backends_fail_actionably():
    # oss is still a stub; aws is a real adapter (tested hermetically in
    # test_cloud_s3.py) whose open() names the missing boto3 client
    be = get_backend("oss")
    assert isinstance(be, ExecutionBackend) and be.wall_clock
    with pytest.raises(NotImplementedError, match="stub"):
        be.open(None)

    import importlib.util

    if importlib.util.find_spec("boto3") is None:
        from repro.serverless.backends.cloud import BackendUnavailableError

        aws = get_backend("aws")
        assert isinstance(aws, ExecutionBackend) and aws.wall_clock
        with pytest.raises(BackendUnavailableError, match="boto3"):
            aws.open(None)


# --------------------------------------------------------------- LocalStore
def test_local_store_blocks_until_visible():
    store = LocalStore(timeout=10.0)
    got = {}

    def consumer():
        got["v"] = store.take("x")

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)                 # consumer is parked on the missing key
    assert t.is_alive()
    store.put("x", 128.0, value="payload")
    t.join(timeout=10.0)
    assert got["v"] == "payload"
    assert "x" not in store and store.live_bytes == 0.0
    assert store.stats.puts == store.stats.deletes == 1


def test_local_store_get_timeout_raises():
    store = LocalStore(timeout=0.1)
    with pytest.raises(TimeoutError, match="never became visible"):
        store.get("missing")


def test_local_store_fs_spill_round_trips(tmp_path):
    store = LocalStore(timeout=5.0, fs_root=str(tmp_path / "objs"))
    arr = np.arange(7, dtype=np.float32)
    store.put("a", arr.nbytes, value=arr)
    np.testing.assert_array_equal(store.get("a"), arr)
    store.delete("a")
    assert len(store) == 0
    # payload file freed with the object
    assert list((tmp_path / "objs").glob("*.pkl")) == []


# --------------------------------------- timing-only parity + conservation
def _timing_plan(d=4):
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    return prof, Config(x=x, d=d, z=tuple(5 for _ in range(L)))


@pytest.mark.parametrize("pipelined", [True, False])
def test_store_traffic_identical_across_backends(pipelined):
    """All backends move the same objects: identical put/get/delete counts
    and (modeled) byte totals for the same plan, conserved and drained —
    threads over dicts, and real OS processes over the file store."""
    prof, cfg = _timing_plan()
    res = {}
    for name in ("emulated", "local", "process"):
        res[name] = run_plan(prof, AWS_LAMBDA, cfg, 32, steps=2,
                             pipelined_sync=pipelined, backend=name)
    se = res["emulated"].store_stats
    for name in ("local", "process"):
        st = res[name].store_stats
        assert (se.puts, se.gets, se.deletes) == \
            (st.puts, st.gets, st.deletes), name
        assert st.bytes_in == pytest.approx(se.bytes_in)
        assert st.bytes_out == pytest.approx(se.bytes_out)
    # conservation (run_plan itself verifies drainage; double-check stats)
    for name, r in res.items():
        st = r.store_stats
        assert st.puts == st.deletes
        assert st.bytes_deleted == pytest.approx(st.bytes_in)
        assert r.backend == name
    assert not res["emulated"].wall_clock
    assert res["local"].wall_clock and res["process"].wall_clock


def test_store_drain_check_catches_leaks():
    from repro.serverless.runtime.store import ObjectStore

    store = ObjectStore()
    store.put("leaked", 64.0)
    with pytest.raises(RuntimeError, match="not drained"):
        store.assert_drained()
    store.delete("leaked")
    store.assert_drained()


@pytest.mark.parametrize("make", [
    lambda: __import__("repro.serverless.runtime.store",
                       fromlist=["ObjectStore"]).ObjectStore(),
    lambda: LocalStore(timeout=1.0),
], ids=["emulated-store", "local-store"])
def test_overwrite_put_counts_implicit_delete(make):
    """Re-putting a key frees the old object; conservation must still hold
    (puts == deletes, bytes_in == bytes_deleted after drain)."""
    store = make()
    store.put("k", 100.0)
    store.put("k", 40.0)                  # overwrite: implicit delete of 100
    assert store.live_bytes == pytest.approx(40.0)
    store.delete("k")
    assert store.stats.puts == store.stats.deletes == 2
    assert store.stats.bytes_deleted == pytest.approx(store.stats.bytes_in)
    from repro.serverless.runtime.store import assert_store_drained

    assert_store_drained(store)


# -------------------------------------------------- numeric K-step parity
def _numeric_setup(n_layers=4, B=8, seq=16, d=2, mu=2, steps=2, seed=0):
    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=n_layers)
    shape = InputShape("bparity", seq, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=seq,
                              micro_batch=B // (d * mu))
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    config = Config(x=x, d=d, z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(seed))
    optimizer = AdamW(lr=1e-2)
    batches = [make_batch(cfg, shape, step=k) for k in range(steps)]
    mk_exec = lambda: Execution(cfg=cfg, optimizer=optimizer,  # noqa: E731
                                init_params=params0,
                                batch_fn=lambda k: batches[k])
    return cfg, prof, config, params0, optimizer, batches, mk_exec


def _assert_bit_identical(a_tree, b_tree):
    la, lb = jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        assert np.array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("pipelined", [True, False],
                         ids=["eq2-pipelined", "eq1-three-phase"])
def test_numeric_params_bit_identical_across_backends(pipelined):
    """Acceptance: K trained steps on the local backend — real concurrent
    stage workers, real store races — and on the process backend — real OS
    processes training through the file store — produce params
    *bit-identical* to the emulated virtual-clock run, for both collective
    schedules, and all track the monolithic fp32 loop."""
    cfg, prof, config, params0, optimizer, batches, mk_exec = _numeric_setup()
    steps = len(batches)
    res = {}
    for name in ("emulated", "local", "process"):
        res[name] = run_plan(prof, AWS_LAMBDA, config, total_micro_batches=4,
                             steps=steps, pipelined_sync=pipelined,
                             execution=mk_exec(), backend=name)
    for name in ("local", "process"):
        _assert_bit_identical(res["emulated"].params, res[name].params)
        assert res["emulated"].losses == res[name].losses, name

    ref_params, ref_losses = _reference_loop(cfg, params0, batches, optimizer,
                                             steps)
    for got, want in zip(res["local"].losses, ref_losses):
        assert abs(got - want) < 2e-4, (got, want)
    name_, err = _param_err(res["local"].params, ref_params)
    assert err < 2e-3, (name_, err)


def test_numeric_parity_on_fs_backed_store(tmp_path):
    """The filesystem-spilling LocalStore round-trips real JAX payloads
    through pickle files without perturbing the numerics."""
    _, prof, config, _, _, _, mk_exec = _numeric_setup(steps=1)
    em = run_plan(prof, AWS_LAMBDA, config, 4, steps=1, execution=mk_exec())
    fs = run_plan(prof, AWS_LAMBDA, config, 4, steps=1, execution=mk_exec(),
                  backend=LocalBackend(fs_root=str(tmp_path / "store")))
    _assert_bit_identical(em.params, fs.params)


def test_local_backend_caps_worker_threads():
    prof, _ = _timing_plan()
    L = prof.L
    cfg = Config(x=tuple(1 for _ in range(L - 1)), d=64,
                 z=tuple(5 for _ in range(L)))
    with pytest.raises(ValueError, match="caps at"):
        run_plan(prof, AWS_LAMBDA, cfg, 64, backend="local")


# ----------------------------------------------------- API surface threading
def test_session_and_plan_emulate_accept_backend(tmp_path):
    from repro.api import DeploymentPlan, session

    s = (session("bert-large", platform="aws", global_batch=64)
         .plan(merge_to=6, d_options=(1, 2))
         .emulate(steps=1, backend="local"))
    assert s.engine_result.backend == "local" and s.engine_result.wall_clock
    path = tmp_path / "plan.json"
    s.save_plan(path)
    plan = DeploymentPlan.load(path)
    res_l = plan.emulate(steps=1, backend="local")
    res_e = plan.emulate(steps=1)
    assert res_l.n_workers == res_e.n_workers
    st_l, st_e = res_l.store_stats, res_e.store_stats
    assert (st_l.puts, st_l.gets, st_l.deletes) == \
        (st_e.puts, st_e.gets, st_e.deletes)


def test_funcpipe_replay_executes_on_backend(tmp_path):
    from repro.api import session
    from repro.serverless.frameworks import funcpipe_replay

    s = session("bert-large", platform="aws", global_batch=64).plan(
        merge_to=6, d_options=(1, 2))
    out = funcpipe_replay([s.deployment_plan], backend="local")
    assert out.engine_results is not None and len(out.engine_results) == 1
    assert out.engine_results[0].backend == "local"
    # default: simulation only, no engine runs
    assert funcpipe_replay([s.deployment_plan]).engine_results is None


def test_cli_saved_plan_replays_on_both_backends(tmp_path, capsys):
    from repro.cli import main as cli_main

    plan_path = tmp_path / "plan.json"
    rc = cli_main(["plan", "--model", "bert-large", "--batch", "64", "--fast",
                   "--plan-cache", str(tmp_path / "cache"),
                   "-o", str(plan_path)])
    assert rc == 0
    rc = cli_main(["emulate", str(plan_path), "--steps", "1",
                   "--backend", "local"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "engine[local]" in out and "host wall-clock" in out
    assert "drained, bytes conserved" in out
    rc = cli_main(["emulate", str(plan_path), "--steps", "1",
                   "--backend", "emulated"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "engine[emulated]" in out and "vs simulator" in out
    # the stubs name the missing client instead of crashing
    with pytest.raises(SystemExit, match="boto3"):
        cli_main(["emulate", str(plan_path), "--backend", "aws"])
    # calibration flags only make sense where real payloads move
    with pytest.raises(SystemExit, match="process"):
        cli_main(["emulate", str(plan_path), "--steps", "1", "--throttle"])
    with pytest.raises(SystemExit, match="process"):
        cli_main(["emulate", str(plan_path), "--steps", "1",
                  "--payload-true", "--backend", "local"])
